//! Request routing across engine replicas (paper §VI-B).
//!
//! The replication study instantiates N identical engines on one GPU and
//! distributes incoming requests among them. The paper splits requests
//! evenly; we provide round-robin (its deterministic equivalent),
//! least-loaded (by queued tokens), hash routing for
//! session-affinity-style workloads, and prefix-affinity routing that
//! keeps every shared-prefix class pinned to the replica holding its
//! cached blocks. [`FairQueue`] adds deficit-weighted round-robin
//! dispatch across tenant classes for the fleet gateway.

use std::collections::{BTreeMap, VecDeque};

use crate::workload::Request;

/// How the router distributes requests among replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through replicas in index order (the paper's even split).
    RoundRobin,
    /// Route to the replica with the fewest outstanding tokens.
    LeastLoaded,
    /// Stable hash of the request id.
    Hash,
    /// Requests sharing a prefix class stick to the replica that first
    /// served the class — its prefix cache already holds the class's
    /// leading blocks, so repeat prompts prefill from cache instead of
    /// recomputing. New classes bind to the least-loaded replica;
    /// requests without a prefix tag fall back to id-hash routing.
    /// Composes with [`Router::route_healthy`]: when a class's replica
    /// is down, the class re-sticks to the re-routed target.
    PrefixAffinity,
}

/// The routing key prefix-affinity sticks on: the request's shared
/// prefix class (per-tenant prefix overrides already namespace their
/// classes disjointly in the workload generator, so tenants never
/// collide here).
fn affinity_class(req: &Request) -> Option<u64> {
    req.prefix.map(|p| p.class)
}

/// Stateful router over `n` replicas.
#[derive(Debug, Clone)]
pub struct Router {
    policy: RoutePolicy,
    n: usize,
    next: usize,
    /// Outstanding token load per replica (LeastLoaded bookkeeping).
    load: Vec<u64>,
    /// Health flags: a downed replica is skipped by
    /// [`Router::route_healthy`] until [`Router::mark_up`].
    down: Vec<bool>,
    /// Sticky prefix-class -> replica bindings (PrefixAffinity only).
    affinity: BTreeMap<u64, usize>,
}

impl Router {
    /// A router over `n` replicas (panics if `n == 0`), all healthy.
    pub fn new(policy: RoutePolicy, n: usize) -> Self {
        assert!(n >= 1);
        Self {
            policy,
            n,
            next: 0,
            load: vec![0; n],
            down: vec![false; n],
            affinity: BTreeMap::new(),
        }
    }

    /// Number of replicas routed over.
    pub fn replicas(&self) -> usize {
        self.n
    }

    /// Policy choice alone, no load bookkeeping.
    fn pick(&mut self, req: &Request) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => {
                let r = self.next;
                self.next = (self.next + 1) % self.n;
                r
            }
            RoutePolicy::LeastLoaded => {
                let (r, _) = self
                    .load
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, &l)| l)
                    .unwrap();
                r
            }
            RoutePolicy::Hash => {
                (req.id.wrapping_mul(0x9E3779B97F4A7C15) >> 32) as usize % self.n
            }
            RoutePolicy::PrefixAffinity => match affinity_class(req) {
                Some(class) => match self.affinity.get(&class) {
                    Some(&r) => r,
                    None => {
                        // First sight of a class: bind it to the
                        // least-loaded replica (deterministic — ties go
                        // to the lowest index) and stick.
                        let (r, _) = self
                            .load
                            .iter()
                            .enumerate()
                            .min_by_key(|(_, &l)| l)
                            .unwrap();
                        self.affinity.insert(class, r);
                        r
                    }
                },
                // Untagged requests have no cache locality to protect:
                // spread them by the same stable id hash Hash uses.
                None => (req.id.wrapping_mul(0x9E3779B97F4A7C15) >> 32) as usize % self.n,
            },
        }
    }

    /// Pick the replica for `req`.
    pub fn route(&mut self, req: &Request) -> usize {
        let r = self.pick(req);
        self.load[r] += req.total_tokens() as u64;
        r
    }

    /// Mark a replica unhealthy (crash window entered).
    pub fn mark_down(&mut self, replica: usize) {
        self.down[replica] = true;
    }

    /// Mark a replica healthy again (restart completed).
    pub fn mark_up(&mut self, replica: usize) {
        self.down[replica] = false;
    }

    /// Whether a replica is currently marked healthy.
    pub fn is_up(&self, replica: usize) -> bool {
        !self.down[replica]
    }

    /// Health-aware routing: run the policy as usual, but if it lands on
    /// a downed replica, re-route to a healthy one (least-loaded picks
    /// the lightest healthy replica; round-robin/hash take the next
    /// healthy index cyclically). Returns `(replica, rerouted)`. When
    /// *every* replica is down the policy choice stands — requests queue
    /// at the dead replica and recover when it restarts, mirroring a
    /// real front-end with nowhere else to send traffic.
    pub fn route_healthy(&mut self, req: &Request) -> (usize, bool) {
        let first = self.pick(req);
        if !self.down[first] || self.down.iter().all(|&d| d) {
            self.load[first] += req.total_tokens() as u64;
            return (first, false);
        }
        let r = match self.policy {
            RoutePolicy::LeastLoaded | RoutePolicy::PrefixAffinity => (0..self.n)
                .filter(|&i| !self.down[i])
                .min_by_key(|&i| self.load[i])
                .unwrap(),
            _ => (first + 1..first + self.n)
                .map(|i| i % self.n)
                .find(|&i| !self.down[i])
                .unwrap(),
        };
        // A re-routed prefix class re-sticks to the replica that now
        // holds (and will cache) its blocks, so the class stays on one
        // healthy replica instead of bouncing per request.
        if self.policy == RoutePolicy::PrefixAffinity {
            if let Some(class) = affinity_class(req) {
                self.affinity.insert(class, r);
            }
        }
        self.load[r] += req.total_tokens() as u64;
        (r, true)
    }

    /// Report completion so LeastLoaded stays accurate.
    pub fn complete(&mut self, replica: usize, req: &Request) {
        self.load[replica] = self.load[replica].saturating_sub(req.total_tokens() as u64);
    }

    /// Partition a whole trace into per-replica traces (the offline
    /// replication experiments route everything up front).
    pub fn partition(&mut self, reqs: &[Request]) -> Vec<Vec<Request>> {
        let mut out = vec![Vec::new(); self.n];
        for r in reqs {
            let i = self.route(r);
            out[i].push(r.clone());
        }
        out
    }
}

/// Deficit-weighted round-robin dispatch queue across tenant classes
/// (the fleet gateway's admission queue).
///
/// Classic DRR: each active class holds a FIFO and a deficit counter;
/// a round visits active classes in order, tops the visited class's
/// deficit up by `quantum × weight`, and dispatches its queued items
/// while the deficit covers their cost (here: total tokens). Over any
/// backlogged interval each class's dispatched cost is proportional to
/// its weight within one `max_cost + quantum × weight` — the bounded
/// cross-tenant unfairness the router proptests pin. A class that
/// drains resets its deficit (no banking credit while idle), and FIFO
/// order within a class is never reordered.
#[derive(Debug, Clone)]
pub struct FairQueue<T> {
    quantum: u64,
    /// Per class: (weight, deficit, FIFO of (cost, item)).
    classes: BTreeMap<u64, (u64, u64, VecDeque<(u64, T)>)>,
    /// Active classes in round-robin visit order.
    active: VecDeque<u64>,
    len: usize,
}

impl<T> FairQueue<T> {
    /// A queue with the given deficit quantum (floored at 1).
    pub fn new(quantum: u64) -> Self {
        Self {
            quantum: quantum.max(1),
            classes: BTreeMap::new(),
            active: VecDeque::new(),
            len: 0,
        }
    }

    /// Queued items across all classes.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether no items are queued.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Enqueue `item` for `class` with the given weight and cost. The
    /// latest weight wins for the whole class; cost is floored at 1 so
    /// a round always makes progress.
    pub fn push(&mut self, class: u64, weight: u64, cost: u64, item: T) {
        let entry = self
            .classes
            .entry(class)
            .or_insert_with(|| (weight.max(1), 0, VecDeque::new()));
        entry.0 = weight.max(1);
        if entry.2.is_empty() {
            self.active.push_back(class);
        }
        entry.2.push_back((cost.max(1), item));
        self.len += 1;
    }

    /// Dispatch the next item under DRR, or `None` when empty.
    pub fn pop(&mut self) -> Option<T> {
        if self.len == 0 {
            return None;
        }
        loop {
            let class = *self.active.front().expect("non-empty queue has an active class");
            let entry = self.classes.get_mut(&class).expect("active class exists");
            let &(cost, _) = entry.2.front().expect("active class has items");
            if entry.1 >= cost {
                entry.1 -= cost;
                let (_, item) = entry.2.pop_front().unwrap();
                self.len -= 1;
                if entry.2.is_empty() {
                    // Idle classes bank no credit.
                    entry.1 = 0;
                    self.active.pop_front();
                }
                return Some(item);
            }
            // Deficit exhausted: top up and move to the round's back.
            // Each visit adds quantum × weight >= 1, so the head item's
            // cost is eventually covered — no livelock.
            entry.1 += self.quantum * entry.0;
            let c = self.active.pop_front().unwrap();
            self.active.push_back(c);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, p: usize, o: usize) -> Request {
        Request {
            id,
            arrival: 0.0,
            prompt_tokens: p,
            output_tokens: o,
            prefix: None,
            predicted: None,
            tenant: None,
        }
    }

    fn preq(id: u64, class: u64) -> Request {
        let mut r = req(id, 100, 50);
        r.prefix = Some(crate::workload::SharedPrefix { class, tokens: 32 });
        r
    }

    #[test]
    fn round_robin_is_even() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 4);
        let reqs: Vec<_> = (0..100).map(|i| req(i, 10, 10)).collect();
        let parts = r.partition(&reqs);
        assert_eq!(parts.len(), 4);
        for p in &parts {
            assert_eq!(p.len(), 25);
        }
    }

    #[test]
    fn least_loaded_balances_token_load() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2);
        // One giant request, then many small ones: smalls should pile on
        // the other replica until loads equalize.
        let giant = req(0, 5000, 1000);
        let g = r.route(&giant);
        let mut counts = [0usize; 2];
        for i in 1..20 {
            let x = req(i, 100, 100);
            counts[r.route(&x)] += 1;
        }
        assert!(counts[1 - g] > counts[g]);
    }

    #[test]
    fn hash_routing_is_stable() {
        let mut r1 = Router::new(RoutePolicy::Hash, 3);
        let mut r2 = Router::new(RoutePolicy::Hash, 3);
        for i in 0..50 {
            let x = req(i, 10, 10);
            assert_eq!(r1.route(&x), r2.route(&x));
        }
    }

    #[test]
    fn complete_reduces_load() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2);
        let a = req(0, 100, 100);
        let ra = r.route(&a);
        r.complete(ra, &a);
        assert_eq!(r.load[ra], 0);
    }

    #[test]
    fn route_healthy_skips_downed_replicas() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 3);
        r.mark_down(1);
        let picks: Vec<_> = (0..6).map(|i| r.route_healthy(&req(i, 10, 10))).collect();
        // RR order 0,1,2,... with 1 rerouted to its next healthy neighbor.
        assert_eq!(
            picks,
            vec![(0, false), (2, true), (2, false), (0, false), (2, true), (2, false)]
        );
        assert!(!r.is_up(1));
    }

    #[test]
    fn route_healthy_falls_back_when_all_down() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 2);
        r.mark_down(0);
        r.mark_down(1);
        // Nowhere to go: the policy pick stands, unrerouted.
        assert_eq!(r.route_healthy(&req(0, 10, 10)), (0, false));
        assert_eq!(r.route_healthy(&req(1, 10, 10)), (1, false));
    }

    #[test]
    fn prefix_affinity_sticks_classes_to_one_replica() {
        let mut r = Router::new(RoutePolicy::PrefixAffinity, 3);
        // Each class binds on first sight and never moves.
        let mut homes = BTreeMap::new();
        for i in 0..60 {
            let x = preq(i, i % 5);
            let replica = r.route(&x);
            let home = homes.entry(i % 5).or_insert(replica);
            assert_eq!(*home, replica, "class {} bounced", i % 5);
        }
        // 5 classes over 3 replicas: least-loaded binding spreads them.
        let distinct: std::collections::BTreeSet<_> = homes.values().collect();
        assert_eq!(distinct.len(), 3, "{homes:?}");
        // Untagged requests spread by id hash, like Hash policy.
        let mut h = Router::new(RoutePolicy::Hash, 3);
        for i in 0..20 {
            assert_eq!(r.pick(&req(i, 10, 10)), h.pick(&req(i, 10, 10)));
        }
    }

    #[test]
    fn prefix_affinity_resticks_when_the_home_replica_goes_down() {
        let mut r = Router::new(RoutePolicy::PrefixAffinity, 3);
        let home = r.route(&preq(0, 7));
        r.mark_down(home);
        let (moved, rerouted) = r.route_healthy(&preq(1, 7));
        assert!(rerouted);
        assert_ne!(moved, home);
        // The class re-stuck: subsequent requests follow without a
        // re-route, even after the old home recovers.
        let (again, rerouted) = r.route_healthy(&preq(2, 7));
        assert_eq!((again, rerouted), (moved, false));
        r.mark_up(home);
        let (after, rerouted) = r.route_healthy(&preq(3, 7));
        assert_eq!((after, rerouted), (moved, false));
    }

    #[test]
    fn fair_queue_splits_service_by_weight() {
        // Two backlogged classes, weights 1:3, unit cost: dispatch
        // order interleaves 1 from class 0 per 3 from class 1.
        let mut q = FairQueue::new(1);
        for i in 0..40u64 {
            q.push(0, 1, 1, ("a", i));
            q.push(1, 3, 1, ("b", i));
        }
        let mut counts = BTreeMap::new();
        for _ in 0..24 {
            let (tag, _) = q.pop().unwrap();
            *counts.entry(tag).or_insert(0usize) += 1;
        }
        // 24 dispatches at 1:3 => 6 vs 18, within one quantum round.
        let a = counts["a"] as i64;
        let b = counts["b"] as i64;
        assert!((a - 6).abs() <= 2 && (b - 18).abs() <= 2, "{counts:?}");
        assert_eq!(q.len(), 80 - 24);
    }

    #[test]
    fn fair_queue_is_fifo_within_a_class_and_drains_empty() {
        let mut q = FairQueue::new(4);
        q.push(5, 2, 3, 10u64);
        q.push(5, 2, 3, 11);
        q.push(5, 2, 3, 12);
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![10, 11, 12]);
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
        // Costly items still dispatch (deficit accumulates past them).
        q.push(0, 1, 1_000_000, 99);
        assert_eq!(q.pop(), Some(99));
    }

    #[test]
    fn mark_up_restores_routing_and_least_loaded_prefers_healthy() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.mark_down(0);
        let (a, rerouted) = r.route_healthy(&req(0, 100, 100));
        // Replica 0 is both least loaded and down -> rerouted to 1.
        assert_eq!((a, rerouted), (1, true));
        r.mark_up(0);
        assert!(r.is_up(0));
        let (b, rerouted) = r.route_healthy(&req(1, 10, 10));
        assert_eq!((b, rerouted), (0, false));
    }
}
