//! Request routing across engine replicas (paper §VI-B).
//!
//! The replication study instantiates N identical engines on one GPU and
//! distributes incoming requests among them. The paper splits requests
//! evenly; we provide round-robin (its deterministic equivalent),
//! least-loaded (by queued tokens), and hash routing for
//! session-affinity-style workloads.

use crate::workload::Request;

/// How the router distributes requests among replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through replicas in index order (the paper's even split).
    RoundRobin,
    /// Route to the replica with the fewest outstanding tokens.
    LeastLoaded,
    /// Stable hash of the request id.
    Hash,
}

/// Stateful router over `n` replicas.
#[derive(Debug, Clone)]
pub struct Router {
    policy: RoutePolicy,
    n: usize,
    next: usize,
    /// Outstanding token load per replica (LeastLoaded bookkeeping).
    load: Vec<u64>,
    /// Health flags: a downed replica is skipped by
    /// [`Router::route_healthy`] until [`Router::mark_up`].
    down: Vec<bool>,
}

impl Router {
    /// A router over `n` replicas (panics if `n == 0`), all healthy.
    pub fn new(policy: RoutePolicy, n: usize) -> Self {
        assert!(n >= 1);
        Self {
            policy,
            n,
            next: 0,
            load: vec![0; n],
            down: vec![false; n],
        }
    }

    /// Number of replicas routed over.
    pub fn replicas(&self) -> usize {
        self.n
    }

    /// Policy choice alone, no load bookkeeping.
    fn pick(&mut self, req: &Request) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => {
                let r = self.next;
                self.next = (self.next + 1) % self.n;
                r
            }
            RoutePolicy::LeastLoaded => {
                let (r, _) = self
                    .load
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, &l)| l)
                    .unwrap();
                r
            }
            RoutePolicy::Hash => {
                (req.id.wrapping_mul(0x9E3779B97F4A7C15) >> 32) as usize % self.n
            }
        }
    }

    /// Pick the replica for `req`.
    pub fn route(&mut self, req: &Request) -> usize {
        let r = self.pick(req);
        self.load[r] += req.total_tokens() as u64;
        r
    }

    /// Mark a replica unhealthy (crash window entered).
    pub fn mark_down(&mut self, replica: usize) {
        self.down[replica] = true;
    }

    /// Mark a replica healthy again (restart completed).
    pub fn mark_up(&mut self, replica: usize) {
        self.down[replica] = false;
    }

    /// Whether a replica is currently marked healthy.
    pub fn is_up(&self, replica: usize) -> bool {
        !self.down[replica]
    }

    /// Health-aware routing: run the policy as usual, but if it lands on
    /// a downed replica, re-route to a healthy one (least-loaded picks
    /// the lightest healthy replica; round-robin/hash take the next
    /// healthy index cyclically). Returns `(replica, rerouted)`. When
    /// *every* replica is down the policy choice stands — requests queue
    /// at the dead replica and recover when it restarts, mirroring a
    /// real front-end with nowhere else to send traffic.
    pub fn route_healthy(&mut self, req: &Request) -> (usize, bool) {
        let first = self.pick(req);
        if !self.down[first] || self.down.iter().all(|&d| d) {
            self.load[first] += req.total_tokens() as u64;
            return (first, false);
        }
        let r = match self.policy {
            RoutePolicy::LeastLoaded => (0..self.n)
                .filter(|&i| !self.down[i])
                .min_by_key(|&i| self.load[i])
                .unwrap(),
            _ => (first + 1..first + self.n)
                .map(|i| i % self.n)
                .find(|&i| !self.down[i])
                .unwrap(),
        };
        self.load[r] += req.total_tokens() as u64;
        (r, true)
    }

    /// Report completion so LeastLoaded stays accurate.
    pub fn complete(&mut self, replica: usize, req: &Request) {
        self.load[replica] = self.load[replica].saturating_sub(req.total_tokens() as u64);
    }

    /// Partition a whole trace into per-replica traces (the offline
    /// replication experiments route everything up front).
    pub fn partition(&mut self, reqs: &[Request]) -> Vec<Vec<Request>> {
        let mut out = vec![Vec::new(); self.n];
        for r in reqs {
            let i = self.route(r);
            out[i].push(r.clone());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, p: usize, o: usize) -> Request {
        Request {
            id,
            arrival: 0.0,
            prompt_tokens: p,
            output_tokens: o,
            prefix: None,
            predicted: None,
        }
    }

    #[test]
    fn round_robin_is_even() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 4);
        let reqs: Vec<_> = (0..100).map(|i| req(i, 10, 10)).collect();
        let parts = r.partition(&reqs);
        assert_eq!(parts.len(), 4);
        for p in &parts {
            assert_eq!(p.len(), 25);
        }
    }

    #[test]
    fn least_loaded_balances_token_load() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2);
        // One giant request, then many small ones: smalls should pile on
        // the other replica until loads equalize.
        let giant = req(0, 5000, 1000);
        let g = r.route(&giant);
        let mut counts = [0usize; 2];
        for i in 1..20 {
            let x = req(i, 100, 100);
            counts[r.route(&x)] += 1;
        }
        assert!(counts[1 - g] > counts[g]);
    }

    #[test]
    fn hash_routing_is_stable() {
        let mut r1 = Router::new(RoutePolicy::Hash, 3);
        let mut r2 = Router::new(RoutePolicy::Hash, 3);
        for i in 0..50 {
            let x = req(i, 10, 10);
            assert_eq!(r1.route(&x), r2.route(&x));
        }
    }

    #[test]
    fn complete_reduces_load() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2);
        let a = req(0, 100, 100);
        let ra = r.route(&a);
        r.complete(ra, &a);
        assert_eq!(r.load[ra], 0);
    }

    #[test]
    fn route_healthy_skips_downed_replicas() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 3);
        r.mark_down(1);
        let picks: Vec<_> = (0..6).map(|i| r.route_healthy(&req(i, 10, 10))).collect();
        // RR order 0,1,2,... with 1 rerouted to its next healthy neighbor.
        assert_eq!(
            picks,
            vec![(0, false), (2, true), (2, false), (0, false), (2, true), (2, false)]
        );
        assert!(!r.is_up(1));
    }

    #[test]
    fn route_healthy_falls_back_when_all_down() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 2);
        r.mark_down(0);
        r.mark_down(1);
        // Nowhere to go: the policy pick stands, unrerouted.
        assert_eq!(r.route_healthy(&req(0, 10, 10)), (0, false));
        assert_eq!(r.route_healthy(&req(1, 10, 10)), (1, false));
    }

    #[test]
    fn mark_up_restores_routing_and_least_loaded_prefers_healthy() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.mark_down(0);
        let (a, rerouted) = r.route_healthy(&req(0, 100, 100));
        // Replica 0 is both least loaded and down -> rerouted to 1.
        assert_eq!((a, rerouted), (1, true));
        r.mark_up(0);
        assert!(r.is_up(0));
        let (b, rerouted) = r.route_healthy(&req(1, 10, 10));
        assert_eq!((b, rerouted), (0, false));
    }
}
