//! Request routing across engine replicas (paper §VI-B).
//!
//! The replication study instantiates N identical engines on one GPU and
//! distributes incoming requests among them. The paper splits requests
//! evenly; we provide round-robin (its deterministic equivalent),
//! least-loaded (by queued tokens), and hash routing for
//! session-affinity-style workloads.

use crate::workload::Request;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    /// Route to the replica with the fewest outstanding tokens.
    LeastLoaded,
    /// Stable hash of the request id.
    Hash,
}

/// Stateful router over `n` replicas.
#[derive(Debug, Clone)]
pub struct Router {
    policy: RoutePolicy,
    n: usize,
    next: usize,
    /// Outstanding token load per replica (LeastLoaded bookkeeping).
    load: Vec<u64>,
}

impl Router {
    pub fn new(policy: RoutePolicy, n: usize) -> Self {
        assert!(n >= 1);
        Self {
            policy,
            n,
            next: 0,
            load: vec![0; n],
        }
    }

    pub fn replicas(&self) -> usize {
        self.n
    }

    /// Pick the replica for `req`.
    pub fn route(&mut self, req: &Request) -> usize {
        let r = match self.policy {
            RoutePolicy::RoundRobin => {
                let r = self.next;
                self.next = (self.next + 1) % self.n;
                r
            }
            RoutePolicy::LeastLoaded => {
                let (r, _) = self
                    .load
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, &l)| l)
                    .unwrap();
                r
            }
            RoutePolicy::Hash => {
                (req.id.wrapping_mul(0x9E3779B97F4A7C15) >> 32) as usize % self.n
            }
        };
        self.load[r] += req.total_tokens() as u64;
        r
    }

    /// Report completion so LeastLoaded stays accurate.
    pub fn complete(&mut self, replica: usize, req: &Request) {
        self.load[replica] = self.load[replica].saturating_sub(req.total_tokens() as u64);
    }

    /// Partition a whole trace into per-replica traces (the offline
    /// replication experiments route everything up front).
    pub fn partition(&mut self, reqs: &[Request]) -> Vec<Vec<Request>> {
        let mut out = vec![Vec::new(); self.n];
        for r in reqs {
            let i = self.route(r);
            out[i].push(r.clone());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, p: usize, o: usize) -> Request {
        Request {
            id,
            arrival: 0.0,
            prompt_tokens: p,
            output_tokens: o,
            prefix: None,
        }
    }

    #[test]
    fn round_robin_is_even() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 4);
        let reqs: Vec<_> = (0..100).map(|i| req(i, 10, 10)).collect();
        let parts = r.partition(&reqs);
        assert_eq!(parts.len(), 4);
        for p in &parts {
            assert_eq!(p.len(), 25);
        }
    }

    #[test]
    fn least_loaded_balances_token_load() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2);
        // One giant request, then many small ones: smalls should pile on
        // the other replica until loads equalize.
        let giant = req(0, 5000, 1000);
        let g = r.route(&giant);
        let mut counts = [0usize; 2];
        for i in 1..20 {
            let x = req(i, 100, 100);
            counts[r.route(&x)] += 1;
        }
        assert!(counts[1 - g] > counts[g]);
    }

    #[test]
    fn hash_routing_is_stable() {
        let mut r1 = Router::new(RoutePolicy::Hash, 3);
        let mut r2 = Router::new(RoutePolicy::Hash, 3);
        for i in 0..50 {
            let x = req(i, 10, 10);
            assert_eq!(r1.route(&x), r2.route(&x));
        }
    }

    #[test]
    fn complete_reduces_load() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2);
        let a = req(0, 100, 100);
        let ra = r.route(&a);
        r.complete(ra, &a);
        assert_eq!(r.load[ra], 0);
    }
}
