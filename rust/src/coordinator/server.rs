//! Online mode: a JSON-lines-over-TCP serving front end (paper §IV's
//! client-server architecture).
//!
//! The offline vendor set has no tokio, so this is a std::net server:
//! one acceptor, a thread per connection, and a single engine worker
//! thread that continuously batches whatever has arrived — which is
//! exactly the continuous-batching semantics the paper's online mode
//! exercises.
//!
//! Protocol (one JSON object per line):
//!   -> {"op":"generate", "prompt_len":32, "max_tokens":16}
//!   <- {"id":7, "tokens":[...], "prompt_len":32, "queue_s":..., "e2e_s":..., "wall_s":...}
//!      (queue_s = submission to first token, e2e_s = submission to last
//!       token, both in the engine's virtual clock; wall_s is host time)
//!   -> {"op":"stats"}
//!   <- {"served":123, "steps":456, "kv_usage":0.41}
//!   -> {"op":"shutdown"}   (stops the server after in-flight work)

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::backend::Backend;
use crate::coordinator::engine::Engine;
use crate::util::json::Json;
use crate::workload::Request;

struct Submission {
    req: Request,
    reply: Sender<Json>,
    submitted_wall: std::time::Instant,
}

/// Per-connection timeout knobs (`--reply-timeout-s`/`--read-timeout-s`).
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// How long a generate op waits for the engine's reply before the
    /// connection gets a structured `{"error":"timeout","id":...}` line.
    pub reply_timeout: Duration,
    /// Per-connection read timeout: a client that connects and then
    /// goes silent is dropped after this long instead of pinning its
    /// handler thread forever (`None` = wait indefinitely).
    pub read_timeout: Option<Duration>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            reply_timeout: Duration::from_secs(600),
            read_timeout: Some(Duration::from_secs(120)),
        }
    }
}

/// Shared server state.
struct Shared {
    tx: Sender<Submission>,
    next_id: AtomicU64,
    served: AtomicU64,
    /// Engine iterations executed (mirrored by the worker for `stats`).
    steps: AtomicU64,
    /// Current KV usage fraction, stored as f64 bits (for `stats`).
    kv_usage_bits: AtomicU64,
    shutdown: AtomicBool,
}

/// Serve `engine` on `addr` until a shutdown op arrives, with default
/// timeouts. Returns the number of requests served.
pub fn serve<B: Backend>(engine: Engine<B>, addr: &str) -> Result<u64> {
    serve_listener(engine, TcpListener::bind(addr)?)
}

/// [`serve`] with explicit timeout configuration.
pub fn serve_with<B: Backend>(engine: Engine<B>, addr: &str, cfg: ServerConfig) -> Result<u64> {
    serve_listener_with(engine, TcpListener::bind(addr)?, cfg)
}

/// Serve `engine` on an already-bound listener (tests bind port 0 and
/// read the ephemeral port back via `listener.local_addr()` before
/// handing the listener over). Returns the number of requests served.
///
/// The engine runs on the *calling* thread (the PJRT backend holds
/// non-Send FFI handles); a spawned acceptor thread owns the listener
/// and hands submissions over an mpsc channel.
pub fn serve_listener<B: Backend>(engine: Engine<B>, listener: TcpListener) -> Result<u64> {
    serve_listener_with(engine, listener, ServerConfig::default())
}

/// [`serve_listener`] with explicit timeout configuration.
pub fn serve_listener_with<B: Backend>(
    engine: Engine<B>,
    listener: TcpListener,
    cfg: ServerConfig,
) -> Result<u64> {
    listener.set_nonblocking(true)?;
    let (tx, rx) = channel::<Submission>();
    let shared = Arc::new(Shared {
        tx,
        next_id: AtomicU64::new(1),
        served: AtomicU64::new(0),
        steps: AtomicU64::new(0),
        kv_usage_bits: AtomicU64::new(0f64.to_bits()),
        shutdown: AtomicBool::new(false),
    });

    let acceptor_shared = shared.clone();
    let acceptor = std::thread::spawn(move || accept_loop(listener, acceptor_shared, cfg));

    // Engine worker: continuous batching over whatever has arrived.
    let served = engine_worker(engine, rx, shared);
    acceptor.join().expect("acceptor panicked")?;
    Ok(served)
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>, cfg: ServerConfig) -> Result<()> {
    let mut conns = Vec::new();
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let s = shared.clone();
                conns.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, s, cfg);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

fn engine_worker<B: Backend>(
    mut engine: Engine<B>,
    rx: Receiver<Submission>,
    shared: Arc<Shared>,
) -> u64 {
    use std::collections::HashMap;
    let mut replies: HashMap<u64, (Sender<Json>, std::time::Instant, f64)> = HashMap::new();
    loop {
        // Drain everything pending; block briefly when idle.
        let mut got = false;
        loop {
            match rx.try_recv() {
                Ok(sub) => {
                    let mut req = sub.req.clone();
                    req.arrival = engine.now();
                    replies.insert(req.id, (sub.reply, sub.submitted_wall, engine.now()));
                    engine.submit(&[req]);
                    got = true;
                }
                Err(_) => break,
            }
        }
        if !engine.has_work() && !got {
            if shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match rx.recv_timeout(Duration::from_millis(5)) {
                Ok(sub) => {
                    let mut req = sub.req.clone();
                    req.arrival = engine.now();
                    replies.insert(req.id, (sub.reply, sub.submitted_wall, engine.now()));
                    engine.submit(&[req]);
                }
                Err(_) => continue,
            }
        }
        if engine.has_work() {
            if engine.step().is_err() {
                break;
            }
            shared
                .steps
                .store(engine.steps_executed() as u64, Ordering::SeqCst);
            shared
                .kv_usage_bits
                .store(engine.kv().usage().to_bits(), Ordering::SeqCst);
        }
        for fin in engine.take_finished() {
            if let Some((reply, wall0, t0)) = replies.remove(&fin.id) {
                shared.served.fetch_add(1, Ordering::SeqCst);
                let gen: Vec<Json> = fin.token_ids[fin.prompt_tokens..]
                    .iter()
                    .map(|&t| Json::num(t as f64))
                    .collect();
                let msg = Json::obj(vec![
                    ("id", Json::num(fin.id as f64)),
                    ("prompt_len", Json::num(fin.prompt_tokens as f64)),
                    ("tokens", Json::arr(gen)),
                    ("queue_s", Json::num(fin.first_token_at - t0)),
                    ("e2e_s", Json::num(fin.finished_at - t0)),
                    ("wall_s", Json::num(wall0.elapsed().as_secs_f64())),
                ]);
                let _ = reply.send(msg);
            }
        }
    }
    shared.served.load(Ordering::SeqCst)
}

fn handle_conn(stream: TcpStream, shared: Arc<Shared>, cfg: ServerConfig) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(cfg.read_timeout)?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            // Read timeout fired: drop the wedged connection so its
            // handler thread does not hang forever.
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                break;
            }
            Err(e) => return Err(e.into()),
        };
        if line.trim().is_empty() {
            continue;
        }
        let msg = match Json::parse(&line) {
            Ok(m) => m,
            Err(e) => {
                writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![("error", Json::str(format!("bad json: {e}")))])
                )?;
                continue;
            }
        };
        match msg.get("op").and_then(|o| o.as_str()) {
            Some("generate") => {
                let prompt_len = msg
                    .get("prompt_len")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(16)
                    .max(1);
                let max_tokens = msg
                    .get("max_tokens")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(16)
                    .max(1);
                let id = shared.next_id.fetch_add(1, Ordering::SeqCst);
                let (reply_tx, reply_rx) = channel();
                shared
                    .tx
                    .send(Submission {
                        req: Request {
                            id,
                            arrival: 0.0,
                            prompt_tokens: prompt_len,
                            output_tokens: max_tokens,
                            prefix: None,
                            predicted: None,
                        },
                        reply: reply_tx,
                        submitted_wall: std::time::Instant::now(),
                    })
                    .ok();
                match reply_rx.recv_timeout(cfg.reply_timeout) {
                    Ok(resp) => writeln!(writer, "{resp}")?,
                    // Structured error carrying the request id, so a
                    // client can correlate the timeout with what it
                    // submitted (and retry idempotently).
                    Err(_) => writeln!(
                        writer,
                        "{}",
                        Json::obj(vec![
                            ("error", Json::str("timeout")),
                            ("id", Json::num(id as f64)),
                        ])
                    )?,
                }
            }
            Some("stats") => {
                writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![
                        (
                            "served",
                            Json::num(shared.served.load(Ordering::SeqCst) as f64)
                        ),
                        (
                            "steps",
                            Json::num(shared.steps.load(Ordering::SeqCst) as f64)
                        ),
                        (
                            "kv_usage",
                            Json::num(f64::from_bits(
                                shared.kv_usage_bits.load(Ordering::SeqCst)
                            ))
                        ),
                    ])
                )?;
            }
            Some("shutdown") => {
                shared.shutdown.store(true, Ordering::SeqCst);
                writeln!(writer, "{}", Json::obj(vec![("ok", Json::Bool(true))]))?;
                break;
            }
            _ => {
                writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![("error", Json::str("unknown op"))])
                )?;
            }
        }
    }
    Ok(())
}

/// Minimal client for tests/examples: send one generate op, wait for
/// the response line.
pub fn client_generate(addr: &str, prompt_len: usize, max_tokens: usize) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(
        stream,
        "{}",
        Json::obj(vec![
            ("op", Json::str("generate")),
            ("prompt_len", Json::num(prompt_len as f64)),
            ("max_tokens", Json::num(max_tokens as f64)),
        ])
    )?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Ok(Json::parse(line.trim())?)
}

/// Minimal client: ask the server for its stats line.
pub fn client_stats(addr: &str) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{}", Json::obj(vec![("op", Json::str("stats"))]))?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Ok(Json::parse(line.trim())?)
}

/// Minimal client: ask the server to shut down.
pub fn client_shutdown(addr: &str) -> Result<()> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{}", Json::obj(vec![("op", Json::str("shutdown"))]))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::SimBackend;
    use crate::coordinator::engine::EngineConfig;
    use crate::gpusim::GpuSpec;
    use crate::models::spec::{AttentionBackendKind, ModelSpec};

    #[test]
    fn serves_generate_requests_over_tcp() {
        let backend = SimBackend::new(
            GpuSpec::h100_64g(),
            ModelSpec::opt_1_3b(),
            AttentionBackendKind::XFormers,
        );
        let engine = Engine::new(backend, EngineConfig::new(8, 4096, 16));
        let addr = "127.0.0.1:47391";
        let server = std::thread::spawn({
            let addr = addr.to_string();
            move || serve(engine, &addr).unwrap()
        });
        // Wait for the listener.
        std::thread::sleep(Duration::from_millis(100));

        let resp = client_generate(addr, 32, 8).unwrap();
        assert_eq!(resp.get("prompt_len").unwrap().as_usize(), Some(32));
        assert_eq!(resp.get("tokens").unwrap().as_arr().unwrap().len(), 8);

        // Concurrent clients batch together.
        let handles: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(move || client_generate(addr, 16, 4).unwrap())
            })
            .collect();
        for h in handles {
            let r = h.join().unwrap();
            assert_eq!(r.get("tokens").unwrap().as_arr().unwrap().len(), 4);
        }

        client_shutdown(addr).unwrap();
        let served = server.join().unwrap();
        assert!(served >= 5, "served {served}");
    }

    #[test]
    fn reply_timeout_returns_structured_error_with_id() {
        let backend = SimBackend::new(
            GpuSpec::h100_64g(),
            ModelSpec::opt_1_3b(),
            AttentionBackendKind::XFormers,
        );
        let engine = Engine::new(backend, EngineConfig::new(8, 4096, 16));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        // A zero reply deadline times out every generate immediately.
        let cfg = ServerConfig {
            reply_timeout: Duration::ZERO,
            read_timeout: Some(Duration::from_secs(5)),
        };
        let server =
            std::thread::spawn(move || serve_listener_with(engine, listener, cfg).unwrap());
        std::thread::sleep(Duration::from_millis(100));

        let resp = client_generate(&addr, 16, 4).unwrap();
        assert_eq!(resp.get("error").and_then(|e| e.as_str()), Some("timeout"));
        // The error carries the request id the server assigned.
        assert!(resp.get("id").and_then(|i| i.as_usize()).is_some(), "{resp}");

        client_shutdown(&addr).unwrap();
        server.join().unwrap();
    }
}
