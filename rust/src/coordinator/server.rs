//! Online mode: a JSON-lines-over-TCP serving front end (paper §IV's
//! client-server architecture).
//!
//! The offline vendor set has no tokio, so this is a std::net server:
//! one acceptor, a thread per connection, and a single engine worker
//! thread that continuously batches whatever has arrived — which is
//! exactly the continuous-batching semantics the paper's online mode
//! exercises.
//!
//! Protocol (one JSON object per line):
//!   -> {"op":"generate", "prompt_len":32, "max_tokens":16}
//!   <- {"id":7, "tokens":[...], "prompt_len":32, "queue_s":..., "e2e_s":..., "wall_s":...}
//!      (queue_s = submission to first token, e2e_s = submission to last
//!       token, both in the engine's virtual clock; wall_s is host time)
//!   -> {"op":"stats"}
//!   <- {"served":123, "steps":456, "kv_usage":0.41}
//!   -> {"op":"shutdown"}   (stops the server after in-flight work)

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::backend::Backend;
use crate::coordinator::engine::Engine;
use crate::coordinator::router::{FairQueue, RoutePolicy, Router};
use crate::util::json::Json;
use crate::workload::{Request, Tenant};

struct Submission {
    req: Request,
    reply: Sender<Json>,
    submitted_wall: std::time::Instant,
}

/// Per-connection timeout knobs (`--reply-timeout-s`/`--read-timeout-s`).
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// How long a generate op waits for the engine's reply before the
    /// connection gets a structured `{"error":"timeout","id":...}` line.
    pub reply_timeout: Duration,
    /// Per-connection read timeout: a client that connects and then
    /// goes silent is dropped after this long instead of pinning its
    /// handler thread forever (`None` = wait indefinitely).
    pub read_timeout: Option<Duration>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            reply_timeout: Duration::from_secs(600),
            read_timeout: Some(Duration::from_secs(120)),
        }
    }
}

/// Shared server state.
struct Shared {
    tx: Sender<Submission>,
    next_id: AtomicU64,
    served: AtomicU64,
    /// Engine iterations executed (mirrored by the worker for `stats`).
    steps: AtomicU64,
    /// Current KV usage fraction, stored as f64 bits (for `stats`).
    kv_usage_bits: AtomicU64,
    shutdown: AtomicBool,
}

/// Serve `engine` on `addr` until a shutdown op arrives, with default
/// timeouts. Returns the number of requests served.
pub fn serve<B: Backend>(engine: Engine<B>, addr: &str) -> Result<u64> {
    serve_listener(engine, TcpListener::bind(addr)?)
}

/// [`serve`] with explicit timeout configuration.
pub fn serve_with<B: Backend>(engine: Engine<B>, addr: &str, cfg: ServerConfig) -> Result<u64> {
    serve_listener_with(engine, TcpListener::bind(addr)?, cfg)
}

/// Serve `engine` on an already-bound listener (tests bind port 0 and
/// read the ephemeral port back via `listener.local_addr()` before
/// handing the listener over). Returns the number of requests served.
///
/// The engine runs on the *calling* thread (the PJRT backend holds
/// non-Send FFI handles); a spawned acceptor thread owns the listener
/// and hands submissions over an mpsc channel.
pub fn serve_listener<B: Backend>(engine: Engine<B>, listener: TcpListener) -> Result<u64> {
    serve_listener_with(engine, listener, ServerConfig::default())
}

/// [`serve_listener`] with explicit timeout configuration.
pub fn serve_listener_with<B: Backend>(
    engine: Engine<B>,
    listener: TcpListener,
    cfg: ServerConfig,
) -> Result<u64> {
    listener.set_nonblocking(true)?;
    let (tx, rx) = channel::<Submission>();
    let shared = Arc::new(Shared {
        tx,
        next_id: AtomicU64::new(1),
        served: AtomicU64::new(0),
        steps: AtomicU64::new(0),
        kv_usage_bits: AtomicU64::new(0f64.to_bits()),
        shutdown: AtomicBool::new(false),
    });

    let acceptor_shared = shared.clone();
    let acceptor = std::thread::spawn(move || accept_loop(listener, acceptor_shared, cfg));

    // Engine worker: continuous batching over whatever has arrived.
    let served = engine_worker(engine, rx, shared);
    acceptor.join().expect("acceptor panicked")?;
    Ok(served)
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>, cfg: ServerConfig) -> Result<()> {
    let mut conns = Vec::new();
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let s = shared.clone();
                conns.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, s, cfg);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

fn engine_worker<B: Backend>(
    mut engine: Engine<B>,
    rx: Receiver<Submission>,
    shared: Arc<Shared>,
) -> u64 {
    use std::collections::HashMap;
    let mut replies: HashMap<u64, (Sender<Json>, std::time::Instant, f64)> = HashMap::new();
    loop {
        // Drain everything pending; block briefly when idle.
        let mut got = false;
        loop {
            match rx.try_recv() {
                Ok(sub) => {
                    let mut req = sub.req.clone();
                    req.arrival = engine.now();
                    replies.insert(req.id, (sub.reply, sub.submitted_wall, engine.now()));
                    engine.submit(&[req]);
                    got = true;
                }
                Err(_) => break,
            }
        }
        if !engine.has_work() && !got {
            if shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match rx.recv_timeout(Duration::from_millis(5)) {
                Ok(sub) => {
                    let mut req = sub.req.clone();
                    req.arrival = engine.now();
                    replies.insert(req.id, (sub.reply, sub.submitted_wall, engine.now()));
                    engine.submit(&[req]);
                }
                Err(_) => continue,
            }
        }
        if engine.has_work() {
            if engine.step().is_err() {
                break;
            }
            shared
                .steps
                .store(engine.steps_executed() as u64, Ordering::SeqCst);
            shared
                .kv_usage_bits
                .store(engine.kv().usage().to_bits(), Ordering::SeqCst);
        }
        for fin in engine.take_finished() {
            if let Some((reply, wall0, t0)) = replies.remove(&fin.id) {
                shared.served.fetch_add(1, Ordering::SeqCst);
                let gen: Vec<Json> = fin.token_ids[fin.prompt_tokens..]
                    .iter()
                    .map(|&t| Json::num(t as f64))
                    .collect();
                let msg = Json::obj(vec![
                    ("id", Json::num(fin.id as f64)),
                    ("prompt_len", Json::num(fin.prompt_tokens as f64)),
                    ("tokens", Json::arr(gen)),
                    ("queue_s", Json::num(fin.first_token_at - t0)),
                    ("e2e_s", Json::num(fin.finished_at - t0)),
                    ("wall_s", Json::num(wall0.elapsed().as_secs_f64())),
                ]);
                let _ = reply.send(msg);
            }
        }
    }
    shared.served.load(Ordering::SeqCst)
}

/// Optional tenant identity on a generate op: `"tenant"` is the class
/// id, `"weight"` its fair-share weight (default 1). Absent = the
/// anonymous single-tenant stream, leaving every tenant path inert.
fn parse_tenant(msg: &Json) -> Option<Tenant> {
    let class = msg.get("tenant").and_then(|v| v.as_u64())?;
    let weight = msg.get("weight").and_then(|v| v.as_u64()).unwrap_or(1);
    Some(Tenant::new(class, weight))
}

fn handle_conn(stream: TcpStream, shared: Arc<Shared>, cfg: ServerConfig) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(cfg.read_timeout)?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            // Read timeout fired: drop the wedged connection so its
            // handler thread does not hang forever.
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                break;
            }
            Err(e) => return Err(e.into()),
        };
        if line.trim().is_empty() {
            continue;
        }
        let msg = match Json::parse(&line) {
            Ok(m) => m,
            Err(e) => {
                writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![("error", Json::str(format!("bad json: {e}")))])
                )?;
                continue;
            }
        };
        match msg.get("op").and_then(|o| o.as_str()) {
            Some("generate") => {
                let prompt_len = msg
                    .get("prompt_len")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(16)
                    .max(1);
                let max_tokens = msg
                    .get("max_tokens")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(16)
                    .max(1);
                let id = shared.next_id.fetch_add(1, Ordering::SeqCst);
                let (reply_tx, reply_rx) = channel();
                shared
                    .tx
                    .send(Submission {
                        req: Request {
                            id,
                            arrival: 0.0,
                            prompt_tokens: prompt_len,
                            output_tokens: max_tokens,
                            prefix: None,
                            predicted: None,
                            tenant: parse_tenant(&msg),
                        },
                        reply: reply_tx,
                        submitted_wall: std::time::Instant::now(),
                    })
                    .ok();
                match reply_rx.recv_timeout(cfg.reply_timeout) {
                    Ok(resp) => writeln!(writer, "{resp}")?,
                    // Structured error carrying the request id, so a
                    // client can correlate the timeout with what it
                    // submitted (and retry idempotently).
                    Err(_) => writeln!(
                        writer,
                        "{}",
                        Json::obj(vec![
                            ("error", Json::str("timeout")),
                            ("id", Json::num(id as f64)),
                        ])
                    )?,
                }
            }
            Some("stats") => {
                writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![
                        (
                            "served",
                            Json::num(shared.served.load(Ordering::SeqCst) as f64)
                        ),
                        (
                            "steps",
                            Json::num(shared.steps.load(Ordering::SeqCst) as f64)
                        ),
                        (
                            "kv_usage",
                            Json::num(f64::from_bits(
                                shared.kv_usage_bits.load(Ordering::SeqCst)
                            ))
                        ),
                    ])
                )?;
            }
            Some("shutdown") => {
                shared.shutdown.store(true, Ordering::SeqCst);
                writeln!(writer, "{}", Json::obj(vec![("ok", Json::Bool(true))]))?;
                break;
            }
            _ => {
                writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![("error", Json::str("unknown op"))])
                )?;
            }
        }
    }
    Ok(())
}

/// Minimal client for tests/examples: send one generate op, wait for
/// the response line.
pub fn client_generate(addr: &str, prompt_len: usize, max_tokens: usize) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(
        stream,
        "{}",
        Json::obj(vec![
            ("op", Json::str("generate")),
            ("prompt_len", Json::num(prompt_len as f64)),
            ("max_tokens", Json::num(max_tokens as f64)),
        ])
    )?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Ok(Json::parse(line.trim())?)
}

/// Minimal client: ask the server for its stats line.
pub fn client_stats(addr: &str) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{}", Json::obj(vec![("op", Json::str("stats"))]))?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Ok(Json::parse(line.trim())?)
}

/// Minimal client: ask the server to shut down.
pub fn client_shutdown(addr: &str) -> Result<()> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{}", Json::obj(vec![("op", Json::str("shutdown"))]))?;
    Ok(())
}

// ======================== fleet gateway ================================
//
// The single-engine server above pins the original protocol. The fleet
// gateway scales the same JSON-lines protocol out to N engine workers
// behind the replication [`Router`]:
//
//   -> {"op":"generate", "prompt_len":32, "max_tokens":4,
//       "tenant":1, "weight":2}            (tenant/weight optional)
//   <- {"event":"token", "id":7, "index":0, "token":1234}   (streamed,
//   <- {"event":"token", "id":7, "index":1, "token":977}     one line
//      ...                                                   per token)
//   <- {"event":"done", "id":7, "prompt_len":32, "tokens":4,
//       "queue_s":..., "e2e_s":..., "wall_s":..., "worker":2, "tenant":1}
//
// Admission is bounded: when `admission_capacity` requests are already
// admitted but unfinished, a generate is rejected *immediately* with
//   <- {"error":"overloaded", "tenant":1, "id":9}
// instead of queueing without bound. Admitted submissions drain through
// a deficit-weighted round-robin [`FairQueue`] keyed by tenant class,
// so a flooding tenant cannot starve a light one at dispatch, and a
// dispatcher thread routes each one via the [`Router`] policy.
//
// Shutdown is a graceful drain: new generates are rejected with
// {"error":"shutting_down"}, the queue drains through the workers, and
// `serve_fleet*` returns the total served count once every in-flight
// sequence has finished.

/// Fleet gateway knobs (`--gateway-*` flags).
#[derive(Debug, Clone, Copy)]
pub struct GatewayConfig {
    /// Per-connection timeout knobs, shared with the single-engine path.
    pub server: ServerConfig,
    /// Admitted-but-unfinished requests the gateway will hold (queued
    /// plus dispatched) before rejecting with `overloaded`.
    pub admission_capacity: usize,
    /// DRR quantum in tokens for cross-tenant dispatch.
    pub quantum: u64,
    /// How the dispatcher spreads requests over the engine workers.
    pub policy: RoutePolicy,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self {
            server: ServerConfig::default(),
            admission_capacity: 256,
            quantum: 256,
            policy: RoutePolicy::LeastLoaded,
        }
    }
}

/// Gateway state behind the admission lock.
struct GatewayQueue {
    /// Deficit-weighted fair dispatch queue over tenant classes.
    queue: FairQueue<Submission>,
    /// Admitted (queued + dispatched) and not yet finished.
    in_flight: usize,
}

/// Shared gateway state.
struct GatewayShared {
    state: Mutex<GatewayQueue>,
    /// Signals the dispatcher that the queue gained work (or shutdown).
    cv: Condvar,
    next_id: AtomicU64,
    served: AtomicU64,
    rejected: AtomicU64,
    shutdown: AtomicBool,
}

/// Serve a fleet of engines on `addr` until a shutdown op arrives.
/// Returns the total requests served across all workers after a
/// graceful drain.
pub fn serve_fleet<B: Backend + Send + 'static>(
    engines: Vec<Engine<B>>,
    addr: &str,
    cfg: GatewayConfig,
) -> Result<u64> {
    serve_fleet_listener(engines, TcpListener::bind(addr)?, cfg)
}

/// [`serve_fleet`] on an already-bound listener (tests bind port 0).
///
/// Unlike [`serve_listener`], every engine runs on its *own* spawned
/// worker thread, so the backend must be `Send` (the simulator backend
/// is; the PJRT backend stays on the single-engine path).
pub fn serve_fleet_listener<B: Backend + Send + 'static>(
    engines: Vec<Engine<B>>,
    listener: TcpListener,
    cfg: GatewayConfig,
) -> Result<u64> {
    anyhow::ensure!(!engines.is_empty(), "fleet gateway needs at least one engine");
    listener.set_nonblocking(true)?;
    let shared = Arc::new(GatewayShared {
        state: Mutex::new(GatewayQueue {
            queue: FairQueue::new(cfg.quantum),
            in_flight: 0,
        }),
        cv: Condvar::new(),
        next_id: AtomicU64::new(1),
        served: AtomicU64::new(0),
        rejected: AtomicU64::new(0),
        shutdown: AtomicBool::new(false),
    });

    let n = engines.len();
    let mut worker_txs = Vec::with_capacity(n);
    let mut workers = Vec::with_capacity(n);
    for (i, engine) in engines.into_iter().enumerate() {
        let (tx, rx) = channel::<Submission>();
        worker_txs.push(tx);
        let s = shared.clone();
        workers.push(std::thread::spawn(move || fleet_worker(engine, rx, s, i)));
    }
    let dispatcher = {
        let s = shared.clone();
        let router = Router::new(cfg.policy, n);
        std::thread::spawn(move || gateway_dispatcher(s, router, worker_txs))
    };
    let acceptor = {
        let s = shared.clone();
        std::thread::spawn(move || fleet_accept_loop(listener, s, cfg))
    };

    acceptor.join().expect("gateway acceptor panicked")?;
    dispatcher.join().expect("gateway dispatcher panicked");
    let mut served = 0;
    for w in workers {
        served += w.join().expect("gateway worker panicked");
    }
    Ok(served)
}

fn fleet_accept_loop(
    listener: TcpListener,
    shared: Arc<GatewayShared>,
    cfg: GatewayConfig,
) -> Result<()> {
    let mut conns = Vec::new();
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let s = shared.clone();
                conns.push(std::thread::spawn(move || {
                    let _ = handle_fleet_conn(stream, s, cfg);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

/// Pop admitted submissions in DRR order and route each to a worker.
/// Exits — dropping the worker senders, which drains the workers — once
/// shutdown is flagged *and* the queue is empty.
fn gateway_dispatcher(
    shared: Arc<GatewayShared>,
    mut router: Router,
    workers: Vec<Sender<Submission>>,
) {
    loop {
        let sub = {
            let mut st = shared.state.lock().expect("gateway lock poisoned");
            loop {
                if let Some(s) = st.queue.pop() {
                    break Some(s);
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                let (guard, _) = shared
                    .cv
                    .wait_timeout(st, Duration::from_millis(50))
                    .expect("gateway lock poisoned");
                st = guard;
            }
        };
        match sub {
            Some(sub) => {
                let w = router.route(&sub.req);
                // A dead worker drops its receiver; the reply channel
                // then times out client-side, which is the same contract
                // as a reply timeout.
                let _ = workers[w].send(sub);
            }
            None => break,
        }
    }
}

/// One engine worker: continuous batching over whatever the dispatcher
/// sent it, streaming token/done event lines back per submission. Runs
/// until the dispatcher hangs up *and* all in-flight work is finished
/// (the graceful drain). Returns its served count.
fn fleet_worker<B: Backend>(
    mut engine: Engine<B>,
    rx: Receiver<Submission>,
    shared: Arc<GatewayShared>,
    worker_idx: usize,
) -> u64 {
    use std::collections::HashMap;
    use std::sync::mpsc::{RecvTimeoutError, TryRecvError};
    let mut replies: HashMap<u64, (Sender<Json>, std::time::Instant, f64)> = HashMap::new();
    let mut served = 0u64;
    let mut disconnected = false;
    let submit = |engine: &mut Engine<B>,
                      replies: &mut HashMap<u64, (Sender<Json>, std::time::Instant, f64)>,
                      sub: Submission| {
        let mut req = sub.req;
        req.arrival = engine.now();
        replies.insert(req.id, (sub.reply, sub.submitted_wall, engine.now()));
        engine.submit(&[req]);
    };
    loop {
        loop {
            match rx.try_recv() {
                Ok(sub) => submit(&mut engine, &mut replies, sub),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        if !engine.has_work() {
            if disconnected {
                break;
            }
            match rx.recv_timeout(Duration::from_millis(5)) {
                Ok(sub) => submit(&mut engine, &mut replies, sub),
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    disconnected = true;
                    continue;
                }
            }
        }
        if engine.has_work() && engine.step().is_err() {
            break;
        }
        for fin in engine.take_finished() {
            if let Some((reply, wall0, t0)) = replies.remove(&fin.id) {
                served += 1;
                shared.served.fetch_add(1, Ordering::SeqCst);
                {
                    let mut st = shared.state.lock().expect("gateway lock poisoned");
                    st.in_flight = st.in_flight.saturating_sub(1);
                }
                let gen = &fin.token_ids[fin.prompt_tokens..];
                for (i, &tok) in gen.iter().enumerate() {
                    let _ = reply.send(Json::obj(vec![
                        ("event", Json::str("token")),
                        ("id", Json::num(fin.id as f64)),
                        ("index", Json::num(i as f64)),
                        ("token", Json::num(tok as f64)),
                    ]));
                }
                let mut done = vec![
                    ("event", Json::str("done")),
                    ("id", Json::num(fin.id as f64)),
                    ("prompt_len", Json::num(fin.prompt_tokens as f64)),
                    ("tokens", Json::num(gen.len() as f64)),
                    ("queue_s", Json::num(fin.first_token_at - t0)),
                    ("e2e_s", Json::num(fin.finished_at - t0)),
                    ("wall_s", Json::num(wall0.elapsed().as_secs_f64())),
                    ("worker", Json::num(worker_idx as f64)),
                ];
                if let Some(t) = fin.tenant {
                    done.push(("tenant", Json::num(t.class as f64)));
                }
                let _ = reply.send(Json::obj(done));
            }
        }
    }
    served
}

fn handle_fleet_conn(stream: TcpStream, shared: Arc<GatewayShared>, cfg: GatewayConfig) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(cfg.server.read_timeout)?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                break;
            }
            Err(e) => return Err(e.into()),
        };
        if line.trim().is_empty() {
            continue;
        }
        let msg = match Json::parse(&line) {
            Ok(m) => m,
            Err(e) => {
                writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![("error", Json::str(format!("bad json: {e}")))])
                )?;
                continue;
            }
        };
        match msg.get("op").and_then(|o| o.as_str()) {
            Some("generate") => {
                let prompt_len = msg
                    .get("prompt_len")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(16)
                    .max(1);
                let max_tokens = msg
                    .get("max_tokens")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(16)
                    .max(1);
                let tenant = parse_tenant(&msg);
                let tenant_json = match tenant {
                    Some(t) => Json::num(t.class as f64),
                    None => Json::Null,
                };
                let id = shared.next_id.fetch_add(1, Ordering::SeqCst);
                if shared.shutdown.load(Ordering::SeqCst) {
                    writeln!(
                        writer,
                        "{}",
                        Json::obj(vec![
                            ("error", Json::str("shutting_down")),
                            ("id", Json::num(id as f64)),
                            ("tenant", tenant_json),
                        ])
                    )?;
                    continue;
                }
                let req = Request {
                    id,
                    arrival: 0.0,
                    prompt_tokens: prompt_len,
                    output_tokens: max_tokens,
                    prefix: None,
                    predicted: None,
                    tenant,
                };
                let (reply_tx, reply_rx) = channel();
                let admitted = {
                    let mut st = shared.state.lock().expect("gateway lock poisoned");
                    if st.in_flight >= cfg.admission_capacity {
                        false
                    } else {
                        st.in_flight += 1;
                        let (class, weight) =
                            tenant.map(|t| (t.class, t.weight)).unwrap_or((0, 1));
                        st.queue.push(
                            class,
                            weight,
                            req.total_tokens() as u64,
                            Submission {
                                req,
                                reply: reply_tx,
                                submitted_wall: std::time::Instant::now(),
                            },
                        );
                        true
                    }
                };
                if !admitted {
                    // Structured backpressure: the client learns *which
                    // tenant* hit the bound and can retry with backoff.
                    shared.rejected.fetch_add(1, Ordering::SeqCst);
                    writeln!(
                        writer,
                        "{}",
                        Json::obj(vec![
                            ("error", Json::str("overloaded")),
                            ("id", Json::num(id as f64)),
                            ("tenant", tenant_json),
                        ])
                    )?;
                    continue;
                }
                shared.cv.notify_one();
                // Stream event lines until the terminal done/error line.
                loop {
                    match reply_rx.recv_timeout(cfg.server.reply_timeout) {
                        Ok(ev) => {
                            let is_done = ev.get("event").and_then(|e| e.as_str())
                                == Some("done")
                                || ev.get("error").is_some();
                            writeln!(writer, "{ev}")?;
                            if is_done {
                                break;
                            }
                        }
                        Err(_) => {
                            writeln!(
                                writer,
                                "{}",
                                Json::obj(vec![
                                    ("error", Json::str("timeout")),
                                    ("id", Json::num(id as f64)),
                                ])
                            )?;
                            break;
                        }
                    }
                }
            }
            Some("stats") => {
                let queued = {
                    let st = shared.state.lock().expect("gateway lock poisoned");
                    st.queue.len()
                };
                writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![
                        (
                            "served",
                            Json::num(shared.served.load(Ordering::SeqCst) as f64)
                        ),
                        (
                            "rejected",
                            Json::num(shared.rejected.load(Ordering::SeqCst) as f64)
                        ),
                        ("queued", Json::num(queued as f64)),
                    ])
                )?;
            }
            Some("shutdown") => {
                shared.shutdown.store(true, Ordering::SeqCst);
                shared.cv.notify_all();
                writeln!(writer, "{}", Json::obj(vec![("ok", Json::Bool(true))]))?;
                break;
            }
            _ => {
                writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![("error", Json::str("unknown op"))])
                )?;
            }
        }
    }
    Ok(())
}

/// Fleet client: send one generate op (optionally tenant-tagged) and
/// collect the streamed event lines through the terminal one. Returns
/// every line received, last one being `done` or an error object.
pub fn client_generate_fleet(
    addr: &str,
    prompt_len: usize,
    max_tokens: usize,
    tenant: Option<(u64, u64)>,
) -> Result<Vec<Json>> {
    let mut stream = TcpStream::connect(addr)?;
    let mut op = vec![
        ("op", Json::str("generate")),
        ("prompt_len", Json::num(prompt_len as f64)),
        ("max_tokens", Json::num(max_tokens as f64)),
    ];
    if let Some((class, weight)) = tenant {
        op.push(("tenant", Json::num(class as f64)));
        op.push(("weight", Json::num(weight as f64)));
    }
    writeln!(stream, "{}", Json::obj(op))?;
    let mut out = Vec::new();
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let ev = Json::parse(line.trim())?;
        let terminal = ev.get("event").and_then(|e| e.as_str()) == Some("done")
            || ev.get("error").is_some();
        out.push(ev);
        if terminal {
            break;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::SimBackend;
    use crate::coordinator::engine::EngineConfig;
    use crate::gpusim::GpuSpec;
    use crate::models::spec::{AttentionBackendKind, ModelSpec};

    #[test]
    fn serves_generate_requests_over_tcp() {
        let backend = SimBackend::new(
            GpuSpec::h100_64g(),
            ModelSpec::opt_1_3b(),
            AttentionBackendKind::XFormers,
        );
        let engine = Engine::new(backend, EngineConfig::new(8, 4096, 16));
        let addr = "127.0.0.1:47391";
        let server = std::thread::spawn({
            let addr = addr.to_string();
            move || serve(engine, &addr).unwrap()
        });
        // Wait for the listener.
        std::thread::sleep(Duration::from_millis(100));

        let resp = client_generate(addr, 32, 8).unwrap();
        assert_eq!(resp.get("prompt_len").unwrap().as_usize(), Some(32));
        assert_eq!(resp.get("tokens").unwrap().as_arr().unwrap().len(), 8);

        // Concurrent clients batch together.
        let handles: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(move || client_generate(addr, 16, 4).unwrap())
            })
            .collect();
        for h in handles {
            let r = h.join().unwrap();
            assert_eq!(r.get("tokens").unwrap().as_arr().unwrap().len(), 4);
        }

        client_shutdown(addr).unwrap();
        let served = server.join().unwrap();
        assert!(served >= 5, "served {served}");
    }

    fn sim_engine() -> Engine<SimBackend> {
        let backend = SimBackend::new(
            GpuSpec::h100_64g(),
            ModelSpec::opt_1_3b(),
            AttentionBackendKind::XFormers,
        );
        Engine::new(backend, EngineConfig::new(8, 4096, 16))
    }

    #[test]
    fn fleet_gateway_streams_token_events_and_drains_gracefully() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            serve_fleet_listener(
                vec![sim_engine(), sim_engine()],
                listener,
                GatewayConfig::default(),
            )
            .unwrap()
        });
        std::thread::sleep(Duration::from_millis(100));

        let evs = client_generate_fleet(&addr, 32, 4, Some((1, 2))).unwrap();
        assert_eq!(evs.len(), 5, "4 token lines + done: {evs:?}");
        for (i, ev) in evs[..4].iter().enumerate() {
            assert_eq!(ev.get("event").and_then(|e| e.as_str()), Some("token"));
            assert_eq!(ev.get("index").and_then(|v| v.as_usize()), Some(i));
            assert!(ev.get("token").and_then(|v| v.as_u64()).is_some());
        }
        let done = evs.last().unwrap();
        assert_eq!(done.get("event").and_then(|e| e.as_str()), Some("done"));
        assert_eq!(done.get("tokens").and_then(|v| v.as_usize()), Some(4));
        assert_eq!(done.get("tenant").and_then(|v| v.as_u64()), Some(1));
        assert!(done.get("worker").and_then(|v| v.as_usize()).unwrap() < 2);

        client_shutdown(&addr).unwrap();
        assert_eq!(server.join().unwrap(), 1);
    }

    #[test]
    fn fleet_gateway_rejects_over_capacity_with_tenant_tagged_backpressure() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        // Zero admission capacity: every generate bounces immediately —
        // the deterministic way to exercise the backpressure line.
        let cfg = GatewayConfig {
            admission_capacity: 0,
            ..GatewayConfig::default()
        };
        let server = std::thread::spawn(move || {
            serve_fleet_listener(vec![sim_engine()], listener, cfg).unwrap()
        });
        std::thread::sleep(Duration::from_millis(100));

        let evs = client_generate_fleet(&addr, 16, 4, Some((3, 1))).unwrap();
        assert_eq!(evs.len(), 1);
        let rej = &evs[0];
        assert_eq!(rej.get("error").and_then(|e| e.as_str()), Some("overloaded"));
        assert_eq!(rej.get("tenant").and_then(|v| v.as_u64()), Some(3));
        // Untagged requests carry tenant:null in the rejection.
        let evs = client_generate_fleet(&addr, 16, 4, None).unwrap();
        assert_eq!(evs[0].get("tenant"), Some(&Json::Null));

        client_shutdown(&addr).unwrap();
        assert_eq!(server.join().unwrap(), 0, "nothing was admitted");
    }

    #[test]
    fn reply_timeout_returns_structured_error_with_id() {
        let backend = SimBackend::new(
            GpuSpec::h100_64g(),
            ModelSpec::opt_1_3b(),
            AttentionBackendKind::XFormers,
        );
        let engine = Engine::new(backend, EngineConfig::new(8, 4096, 16));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        // A zero reply deadline times out every generate immediately.
        let cfg = ServerConfig {
            reply_timeout: Duration::ZERO,
            read_timeout: Some(Duration::from_secs(5)),
        };
        let server =
            std::thread::spawn(move || serve_listener_with(engine, listener, cfg).unwrap());
        std::thread::sleep(Duration::from_millis(100));

        let resp = client_generate(&addr, 16, 4).unwrap();
        assert_eq!(resp.get("error").and_then(|e| e.as_str()), Some("timeout"));
        // The error carries the request id the server assigned.
        assert!(resp.get("id").and_then(|i| i.as_usize()).is_some(), "{resp}");

        client_shutdown(&addr).unwrap();
        server.join().unwrap();
    }
}
