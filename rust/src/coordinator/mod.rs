//! L3 serving coordinator: the vLLM-like engine the paper instruments.
//!
//! - [`request`]  — request lifecycle and per-sequence state.
//! - [`scheduler`] — continuous-batching policy (prefill-priority like
//!   vLLM's default, plus Sarathi-style chunked prefill), admission
//!   control charged by net-new KV blocks, preemption mode selection
//!   (recompute vs swap).
//! - [`engine`]   — the step loop driving a [`Backend`](crate::backend::Backend):
//!   builds batches (block tables / slot mappings), advances the clock,
//!   records metrics and (when simulating) the kernel timeline.
//! - [`offline`]  — the paper's §V offline mode: fixed-length requests,
//!   everything at t=0, direct step calls.
//! - [`online`]   — arrival-driven serving in virtual time: Poisson /
//!   bursty / trace-replay workloads, percentile latency summaries and
//!   SLO attainment (the scenario the joint batch×replica planner
//!   optimizes).
//! - [`router`]   — request routing across engine replicas (§VI-B).
//! - [`server`]   — online mode: JSON-lines-over-TCP client/server
//!   (std::net + threads; tokio is outside the offline vendor set).
//! - [`disagg`]   — disaggregated prefill/decode pools with a modeled
//!   KV-migration handoff (NVLink within a node, PCIe across).

pub mod disagg;
pub mod engine;
pub mod offline;
pub mod online;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;

pub use disagg::{run_disagg, DisaggConfig, DisaggReport, MigrateLink};
pub use engine::{Engine, EngineConfig, EngineReport};
pub use online::{run_online, OnlineConfig, OnlineReport};
pub use request::{RequestState, RunningSeq};
pub use scheduler::{PreemptMode, ScheduleDecision, Scheduler, SchedulerPolicy};
