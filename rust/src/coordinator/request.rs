//! Request lifecycle: waiting -> running (prefilled) -> finished, with
//! preemption back to waiting (recompute policy, as in vLLM) or out to
//! the CPU swap pool (swap policy — `PreemptMode::Swap`).

use crate::kvcache::SeqId;
use crate::workload::Request;

/// Where a request is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    /// Arrived but not yet admitted to a batch.
    Waiting,
    /// Admitted: holding KV blocks, prefilling or decoding.
    Running,
    /// Generated its full target output; awaiting collection.
    Finished,
    /// Evicted under memory pressure; re-prefills from the prompt
    /// (recompute policy).
    Preempted,
    /// Evicted to the CPU swap pool; resumes decoding after swap-in
    /// (no re-prefill, unlike [`RequestState::Preempted`]).
    Swapped,
}

/// A sequence admitted to the engine.
#[derive(Debug, Clone)]
pub struct RunningSeq {
    /// Sequence id (the originating request's id).
    pub id: SeqId,
    /// Virtual arrival time inherited from the request (seconds).
    pub arrival: f64,
    /// Prompt length in tokens.
    pub prompt_tokens: usize,
    /// Output tokens to generate before finishing.
    pub target_output: usize,
    /// Tokens generated so far.
    pub generated: usize,
    /// Full token-id history (prompt + generated) — needed by the PJRT
    /// backend; the simulator ignores the values.
    pub token_ids: Vec<i32>,
    /// Current lifecycle state.
    pub state: RequestState,
    /// Times the request was preempted (recompute restarts the prompt).
    pub preemptions: u32,
    /// Virtual time the first token completed (set once; preserved
    /// across preemption since the token was already delivered).
    pub first_token_at: Option<f64>,
    /// Prompt tokens already prefilled into the KV cache (chunked
    /// prefill admits a long prompt over several steps). `0` until the
    /// first chunk lands; equals [`RunningSeq::prefill_len`] once the
    /// sequence starts decoding. Reset by recompute-preemption, which
    /// frees the blocks and re-prefills from scratch.
    pub prefilled: usize,
    /// The originating request's shared-prefix tag, kept so a replica
    /// crash can rebuild the *original* request (same prefix class ⇒
    /// bit-identical token resynthesis) for recompute-from-prompt.
    pub prefix: Option<crate::workload::SharedPrefix>,
    /// S³-style predicted output length carried from the request:
    /// expected-footprint admission and overrun-targeted preemption
    /// consult it; decoding itself always runs to `target_output`.
    pub predicted: Option<usize>,
    /// Tenant identity carried from the request: fair-share admission
    /// and per-tenant report breakdowns consult it; `None` (the
    /// anonymous single-tenant stream) leaves every such path inert.
    pub tenant: Option<crate::workload::Tenant>,
}

impl RunningSeq {
    /// Deterministic synthetic prompt ids: hash(key, position) % vocab,
    /// where `key` is the request id — or, for the leading
    /// `prefix.tokens` positions, the shared prefix class, so every
    /// request of a class opens with the *same* token ids and a
    /// prefix-aware KV cache can share their leading blocks. Real
    /// deployments would take these from the tokenizer; content is
    /// irrelevant to every timing experiment in the paper.
    pub fn from_request(req: &Request, vocab: usize) -> Self {
        let (class_key, prefix_tokens) = match req.prefix {
            // `!class` keeps class keys disjoint from real request ids.
            Some(p) => (!p.class, p.tokens.min(req.prompt_tokens)),
            None => (0, 0),
        };
        let mut token_ids = Vec::with_capacity(req.prompt_tokens);
        for pos in 0..req.prompt_tokens {
            let key = if pos < prefix_tokens {
                class_key
            } else {
                req.id
            };
            let h = key
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(pos as u64)
                .wrapping_mul(0xBF58476D1CE4E5B9);
            // Keep 0 free for padding.
            token_ids.push((1 + (h % (vocab as u64 - 1))) as i32);
        }
        Self {
            id: req.id,
            arrival: req.arrival,
            prompt_tokens: req.prompt_tokens,
            target_output: req.output_tokens,
            generated: 0,
            token_ids,
            state: RequestState::Waiting,
            preemptions: 0,
            first_token_at: None,
            prefilled: 0,
            prefix: req.prefix,
            predicted: req.predicted,
            tenant: req.tenant,
        }
    }

    /// How far generation has run past the predicted output length
    /// (0 while at or under prediction, or when unpredicted). The
    /// preemption policy victimizes the largest overrun first: a
    /// sequence past its prediction holds KV blocks the admission
    /// charge never budgeted for.
    pub fn overrun(&self) -> usize {
        match self.predicted {
            Some(p) => self.generated.saturating_sub(p),
            None => 0,
        }
    }

    /// Context length after prefill + generation so far.
    pub fn context_len(&self) -> usize {
        self.prompt_tokens + self.generated
    }

    /// Whether the sequence has generated its full target output.
    pub fn is_finished(&self) -> bool {
        self.generated >= self.target_output
    }

    /// Append one generated token.
    pub fn push_token(&mut self, tok: i32) {
        self.token_ids.push(tok);
        self.generated += 1;
    }

    /// Reset to the waiting state for recompute-preemption: generated
    /// tokens are *kept* in token_ids (they re-prefill as prompt), and
    /// chunked-prefill progress restarts because the blocks are freed.
    pub fn preempt(&mut self) {
        self.state = RequestState::Preempted;
        self.preemptions += 1;
        self.prefilled = 0;
    }

    /// Effective prompt length for (re-)prefill.
    pub fn prefill_len(&self) -> usize {
        self.token_ids.len()
    }

    /// Prompt tokens still awaiting prefill (chunked prefill feeds
    /// these across steps; whole-prompt prefill feeds them at once).
    pub fn remaining_prefill(&self) -> usize {
        self.prefill_len().saturating_sub(self.prefilled)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, p: usize, o: usize) -> Request {
        Request {
            id,
            arrival: 0.0,
            prompt_tokens: p,
            output_tokens: o,
            prefix: None,
            predicted: None,
            tenant: None,
        }
    }

    #[test]
    fn overrun_counts_tokens_past_prediction() {
        let mut r = req(1, 5, 10);
        r.predicted = Some(2);
        let mut s = RunningSeq::from_request(&r, 100);
        assert_eq!(s.predicted, Some(2));
        assert_eq!(s.overrun(), 0);
        s.push_token(7);
        s.push_token(8);
        assert_eq!(s.overrun(), 0);
        s.push_token(9);
        assert_eq!(s.overrun(), 1);
        // Unpredicted sequences never report overrun.
        let mut plain = RunningSeq::from_request(&req(2, 5, 10), 100);
        plain.push_token(7);
        assert_eq!(plain.overrun(), 0);
    }

    #[test]
    fn synthetic_prompt_is_deterministic_and_in_vocab() {
        let a = RunningSeq::from_request(&req(3, 50, 10), 8192);
        let b = RunningSeq::from_request(&req(3, 50, 10), 8192);
        assert_eq!(a.token_ids, b.token_ids);
        assert!(a.token_ids.iter().all(|&t| t >= 1 && (t as usize) < 8192));
        let c = RunningSeq::from_request(&req(4, 50, 10), 8192);
        assert_ne!(a.token_ids, c.token_ids);
    }

    #[test]
    fn lifecycle_counters() {
        let mut s = RunningSeq::from_request(&req(1, 5, 3), 100);
        assert_eq!(s.context_len(), 5);
        s.push_token(7);
        s.push_token(8);
        assert_eq!(s.context_len(), 7);
        assert!(!s.is_finished());
        s.push_token(9);
        assert!(s.is_finished());
        assert_eq!(s.token_ids.len(), 8);
    }

    #[test]
    fn shared_prefix_classes_share_leading_tokens() {
        use crate::workload::SharedPrefix;
        let with = |id: u64, class: u64| {
            let mut r = req(id, 40, 5);
            r.prefix = Some(SharedPrefix { class, tokens: 24 });
            RunningSeq::from_request(&r, 8192)
        };
        let a = with(1, 0);
        let b = with(2, 0);
        let c = with(3, 1);
        // Same class: identical leading 24 tokens, divergent after.
        assert_eq!(a.token_ids[..24], b.token_ids[..24]);
        assert_ne!(a.token_ids[24..], b.token_ids[24..]);
        // Different class: different prefix.
        assert_ne!(a.token_ids[..24], c.token_ids[..24]);
        // No prefix: bit-identical to the pre-prefix synthesis.
        let plain = RunningSeq::from_request(&req(1, 40, 5), 8192);
        assert_ne!(plain.token_ids, a.token_ids);
        assert!(plain.token_ids.iter().all(|&t| t >= 1));
    }

    #[test]
    fn preemption_keeps_generated_tokens_for_recompute() {
        let mut s = RunningSeq::from_request(&req(1, 5, 10), 100);
        s.push_token(42);
        s.prefilled = 6;
        s.preempt();
        assert_eq!(s.preemptions, 1);
        assert_eq!(s.prefill_len(), 6); // prompt + 1 generated
        assert_eq!(s.generated, 1);
        // Recompute frees the blocks: chunk progress restarts.
        assert_eq!(s.prefilled, 0);
        assert_eq!(s.remaining_prefill(), 6);
    }

    #[test]
    fn chunk_progress_tracks_remaining_prefill() {
        let mut s = RunningSeq::from_request(&req(1, 100, 4), 1000);
        assert_eq!(s.remaining_prefill(), 100);
        s.prefilled = 64;
        assert_eq!(s.remaining_prefill(), 36);
        s.prefilled = 100;
        assert_eq!(s.remaining_prefill(), 0);
    }
}
