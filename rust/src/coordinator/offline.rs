//! Offline mode (paper §IV/§V): fixed-length synthetic requests, all
//! arriving at t=0, executed by direct step calls — the setup every
//! GPU-profiling experiment uses (161 in / 338 out, the ShareGPT means).

use anyhow::Result;

use crate::backend::SimBackend;
use crate::bca::controller::ControllerConfig;
use crate::coordinator::engine::{Engine, EngineConfig, EngineReport};
use crate::coordinator::scheduler::{PreemptMode, SchedulerPolicy};
use crate::faults::FaultPlan;
use crate::gpusim::GpuSpec;
use crate::kvcache;
use crate::models::spec::{AttentionBackendKind, ModelSpec};
use crate::workload::{generate, PredictorConfig, SharedPrefixConfig, TenantsConfig, WorkloadConfig};

/// Configuration of one offline simulated run.
#[derive(Debug, Clone)]
pub struct OfflineConfig {
    /// GPU the simulated engine runs on.
    pub gpu: GpuSpec,
    /// Model being served.
    pub model: ModelSpec,
    /// Attention kernel cost model (xFormers or FlashAttention).
    pub attention: AttentionBackendKind,
    /// Max batch size knob (vLLM `max_num_seqs`).
    pub max_num_seqs: usize,
    /// Memory fraction this engine may use (1.0 = the whole 90% budget;
    /// BCA/replication pass smaller fractions).
    pub mem_fraction: f64,
    /// Synthetic requests to generate.
    pub num_requests: usize,
    /// Prompt length of every synthetic request (tokens).
    pub input_len: usize,
    /// Output length of every synthetic request (tokens).
    pub output_len: usize,
    /// Sarathi-style chunked prefill instead of prefill-priority.
    pub chunked_prefill: bool,
    /// Preemption style when the KV pool runs dry.
    pub preempt: PreemptMode,
    /// Share full prompt blocks by content hash (KV cache v2).
    pub prefix_cache: bool,
    /// Shared system-prompt classes layered over the workload.
    pub prefix: Option<SharedPrefixConfig>,
    /// Record the per-step kernel timeline (disables fast-forward).
    pub record_steps: bool,
    /// Event-driven fast-forward between scheduler events (default on;
    /// `--no-fast-forward` falls back to the stepwise golden reference).
    pub fast_forward: bool,
    /// KV-cache block size in token slots.
    pub block_size: usize,
    /// Tensor-parallel degree: the engine shards the model across `tp`
    /// GPUs (Megatron heads/FFN/vocab split + ring collectives) and its
    /// KV pool is sized per rank. 1 = today's single-GPU engine,
    /// bit-identical to before the knob existed.
    pub tp: usize,
    /// Deterministic fault schedule (`--fault-*` flags); `None` is a
    /// fault-free run, bit-identical to the pre-fault engine.
    pub faults: Option<FaultPlan>,
    /// Closed-loop AIMD admission controller (`--controller-*` flags);
    /// `None` keeps the static `max_num_seqs`, bit-identical to the
    /// pre-controller engine.
    pub controller: Option<ControllerConfig>,
    /// S³-style output-length predictor attached to the generated
    /// workload (`--predict-*` flags); `None` leaves requests
    /// unpredicted (legacy admission and preemption).
    pub predictor: Option<PredictorConfig>,
    /// Multi-tenant shaping of the generated workload (`--tenants` /
    /// `--tenant-weights`); `None` is the anonymous single-tenant
    /// stream, bit-identical to the pre-tenant engine.
    pub tenants: Option<TenantsConfig>,
    /// Weighted fair-share admission within the engine (`--fair-share`);
    /// `false` keeps plain FCFS admission.
    pub fair_share: bool,
}

impl OfflineConfig {
    /// Defaults for one offline run: H100-64G, ShareGPT mean lengths,
    /// every optional subsystem off.
    pub fn new(model: ModelSpec, max_num_seqs: usize) -> Self {
        Self {
            gpu: GpuSpec::h100_64g(),
            model,
            attention: AttentionBackendKind::XFormers,
            max_num_seqs,
            mem_fraction: 1.0,
            num_requests: 2 * max_num_seqs.max(8),
            input_len: crate::workload::SHAREGPT_MEAN_INPUT,
            output_len: crate::workload::SHAREGPT_MEAN_OUTPUT,
            chunked_prefill: false,
            preempt: PreemptMode::Recompute,
            prefix_cache: false,
            prefix: None,
            record_steps: false,
            fast_forward: true,
            block_size: 16,
            tp: 1,
            faults: None,
            controller: None,
            predictor: None,
            tenants: None,
            fair_share: false,
        }
    }

    /// Build the engine. Panics if `tp` does not divide the model's
    /// sharded dimensions — CLI and planner validate before reaching
    /// here, so a bad degree this deep is a programming error.
    pub fn build_engine(&self) -> Engine<SimBackend> {
        let kv_blocks = kvcache::capacity_blocks_tp(
            &self.gpu,
            &self.model,
            self.block_size,
            self.mem_fraction,
            self.tp,
        )
        .max(2);
        let backend =
            SimBackend::with_tp(self.gpu.clone(), self.model.clone(), self.attention, self.tp)
                .expect("tp must divide the model's sharded dimensions");
        let mut cfg = EngineConfig::new(self.max_num_seqs, kv_blocks + 1, self.block_size);
        cfg.max_blocks_per_seq = (self.model.max_seq + self.block_size - 1) / self.block_size;
        cfg.record_steps = self.record_steps;
        cfg.fast_forward = self.fast_forward;
        cfg.preempt = self.preempt;
        cfg.prefix_cache = self.prefix_cache;
        cfg.faults = self.faults.clone();
        cfg.controller = self.controller.clone();
        cfg.fair_share = self.fair_share;
        if self.chunked_prefill {
            cfg.policy = SchedulerPolicy::ChunkedPrefill;
        }
        Engine::new(backend, cfg)
    }

    /// Run the configured workload to completion.
    pub fn run(&self) -> Result<EngineReport> {
        let mut engine = self.build_engine();
        engine.submit(&generate(&WorkloadConfig {
            prefix: self.prefix,
            predictor: self.predictor,
            tenants: self.tenants.clone(),
            ..WorkloadConfig::offline(self.num_requests, self.input_len, self.output_len)
        }));
        engine.run_to_completion()
    }

    /// Run the paper's *online-mode* workload (ShareGPT-like lengths)
    /// through the same engine — used by Figs 2/3 and Table IV.
    pub fn run_sharegpt(&self, num_requests: usize, seed: u64) -> Result<EngineReport> {
        let mut engine = self.build_engine();
        engine.submit(&generate(&WorkloadConfig {
            prefix: self.prefix,
            predictor: self.predictor,
            tenants: self.tenants.clone(),
            ..WorkloadConfig::sharegpt(num_requests, seed)
        }));
        engine.run_to_completion()
    }
}

/// Sweep `max_num_seqs` over `batches`, returning (batch, report) —
/// the x-axis loop behind Figs 2/3/10.
///
/// Every grid point is an independent engine run over its own workload
/// copy, so the points fan out across scoped threads
/// (`util::par::par_map`); results come back in grid order, keeping
/// figure rows deterministic.
pub fn sweep_batch_sizes(
    base: &OfflineConfig,
    batches: &[usize],
    sharegpt: bool,
    num_requests: usize,
) -> Result<Vec<(usize, EngineReport)>> {
    let reports = crate::util::par::par_map(batches, |&b| {
        let mut cfg = base.clone();
        cfg.max_num_seqs = b;
        cfg.num_requests = num_requests;
        if sharegpt {
            cfg.run_sharegpt(num_requests, 0)
        } else {
            cfg.run()
        }
    });
    batches
        .iter()
        .zip(reports)
        .map(|(&b, r)| Ok((b, r?)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offline_run_completes_and_reports() {
        let mut cfg = OfflineConfig::new(ModelSpec::opt_1_3b(), 16);
        cfg.num_requests = 32;
        cfg.input_len = 64;
        cfg.output_len = 32;
        let r = cfg.run().unwrap();
        assert_eq!(r.metrics.completed, 32);
        assert!(r.decode_time > r.prefill_time);
        assert!(r.peak_kv_usage > 0.0 && r.peak_kv_usage <= 1.0);
    }

    #[test]
    fn mem_fraction_limits_kv_and_throughput() {
        let mut full = OfflineConfig::new(ModelSpec::opt_1_3b(), 256);
        full.num_requests = 256;
        full.output_len = 16;
        let mut tight = full.clone();
        tight.mem_fraction = 0.08;
        let rf = full.run().unwrap();
        let rt = tight.run().unwrap();
        // The tight engine has far fewer blocks -> higher peak usage and
        // (with preemptions) no better throughput.
        assert!(rt.peak_kv_usage >= rf.peak_kv_usage);
        assert!(rt.metrics.throughput_tps <= rf.metrics.throughput_tps * 1.05);
    }

    #[test]
    fn tp_engine_completes_faster_steps_but_same_cpu_gaps() {
        let mut cfg = OfflineConfig::new(ModelSpec::opt_1_3b(), 32);
        cfg.num_requests = 64;
        cfg.input_len = 100;
        cfg.output_len = 24;
        let solo = cfg.run().unwrap();
        cfg.tp = 2;
        let sharded = cfg.run().unwrap();
        assert_eq!(sharded.metrics.completed, 64);
        // Same schedule shape (token counts force the same step count
        // on an ample pool), less GPU time per step.
        assert!(
            sharded.metrics.makespan < solo.metrics.makespan,
            "tp2 {} vs tp1 {}",
            sharded.metrics.makespan,
            solo.metrics.makespan
        );
    }

    #[test]
    fn single_class_fair_share_matches_fcfs_and_weighted_classes_complete() {
        let mut cfg = OfflineConfig::new(ModelSpec::opt_1_3b(), 8);
        cfg.num_requests = 24;
        cfg.input_len = 64;
        cfg.output_len = 16;
        let base = cfg.run().unwrap();
        // One default-weight class under fair share: the weighted-RR
        // replay degenerates to queue order, so the run is identical.
        cfg.tenants = Some(crate::workload::TenantsConfig::even(1));
        cfg.fair_share = true;
        let one = cfg.run().unwrap();
        assert_eq!(one.metrics.completed, base.metrics.completed);
        assert_eq!(one.metrics.makespan, base.metrics.makespan);
        assert_eq!(one.metrics.throughput_tps, base.metrics.throughput_tps);
        // Three weighted classes still drain the whole workload.
        cfg.tenants = Some(crate::workload::TenantsConfig::weighted(&[1, 2, 4]));
        let many = cfg.run().unwrap();
        assert_eq!(many.metrics.completed, 24);
    }

    #[test]
    fn sharegpt_mode_runs() {
        let cfg = OfflineConfig::new(ModelSpec::opt_1_3b(), 32);
        let r = cfg.run_sharegpt(64, 1).unwrap();
        assert_eq!(r.metrics.completed, 64);
        assert!(r.metrics.avg_batch > 1.0);
    }
}
