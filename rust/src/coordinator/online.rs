//! Online serving in virtual time (tentpole of the arrival-driven
//! scenario class; paper discussion §VII and the SLA-constrained
//! batching literature it cites).
//!
//! The offline drivers submit everything at t=0, so the engine never
//! idles and SLOs never bind. This driver feeds the *same* engine an
//! arrival-stamped trace ([`ArrivalPattern::Poisson`], bursty, or a
//! replayed trace): the engine's clock advances only by the simulated
//! per-step CPU gap + GPU time (plus recorded idle waits), and a
//! request joins the batch only once the virtual clock has passed its
//! arrival. Everything is deterministic — same seed, same report,
//! bit for bit, regardless of worker-thread count.
//!
//! As requests finish, the driver streams their TTFT/ITL/E2E into
//! [`StreamingSummary`] accumulators and checks them against the
//! [`Slo`]; the final [`OnlineReport`] carries p50/p90/p99 summaries,
//! the SLO-attainment fraction, and **goodput** (SLO-met completed
//! requests per second) — the objective the joint batch×replica
//! planner in [`crate::bca::planner`] maximizes.

use anyhow::Result;

use crate::bca::controller::ControllerReport;
use crate::coordinator::offline::OfflineConfig;
use crate::faults::FaultStats;
use crate::metrics::{
    Percentiles, PredictionStats, RequestLatency, RunMetrics, Slo, StreamingSummary,
    TenantBreakdown,
};
use crate::util::json::Json;
use crate::workload::{generate, ArrivalPattern, WorkloadConfig};

/// Configuration of one online (arrival-driven) run.
#[derive(Debug, Clone)]
pub struct OnlineConfig {
    /// Engine/model/memory knobs (its request-count fields are unused —
    /// the workload below is the source of truth).
    pub engine: OfflineConfig,
    /// Arrival-stamped workload to serve.
    pub workload: WorkloadConfig,
    /// Latency objective the report grades against.
    pub slo: Slo,
}

impl OnlineConfig {
    /// ShareGPT-like lengths, Poisson arrivals at `rate` req/s.
    pub fn poisson(engine: OfflineConfig, num_requests: usize, rate: f64, seed: u64) -> Self {
        Self {
            engine,
            workload: WorkloadConfig::poisson(num_requests, rate, seed),
            slo: Slo::default(),
        }
    }
}

/// Result of one online run: the percentile/SLO view of a serving
/// trace. Serializes to deterministic JSON via [`OnlineReport::to_json`].
#[derive(Debug, Clone)]
pub struct OnlineReport {
    /// Model served.
    pub model: String,
    /// Requests in the arrival trace.
    pub num_requests: usize,
    /// Requests that ran to completion.
    pub completed: usize,
    /// Long-run offered load (req/s): the configured pattern rate, or
    /// `num_requests / last_arrival` for replayed traces (0 when all
    /// requests arrive at t=0).
    pub offered_rps: f64,
    /// Virtual time from t=0 to the last completion (seconds).
    pub makespan: f64,
    /// Generated tokens per second of makespan.
    pub throughput_tps: f64,
    /// Time-to-first-token summary (seconds).
    pub ttft: Percentiles,
    /// Per-request mean inter-token-latency summary (seconds).
    pub itl: Percentiles,
    /// End-to-end latency summary (seconds).
    pub e2e: Percentiles,
    /// The SLO the run was graded against.
    pub slo: Slo,
    /// Fraction of completed requests meeting the SLO.
    pub attainment: f64,
    /// SLO-met completed requests per second of makespan.
    pub goodput_rps: f64,
    /// Peak arrived-but-unscheduled backlog observed across steps
    /// (never-admitted arrivals plus preempted sequences awaiting
    /// re-prefill or swap-in).
    pub peak_queue_depth: usize,
    /// Peak fraction of the KV pool in use.
    pub peak_kv_usage: f64,
    /// Total preemption events across the run.
    pub preemptions: u64,
    /// Preemptions served by swap (PCIe transfer instead of recompute).
    pub swap_outs: u64,
    /// Prefix-cache hit rate over full prompt blocks (0 when disabled).
    pub prefix_hit_rate: f64,
    /// Engine steps executed (fast-forward jumps count as one).
    pub steps: usize,
    /// Availability accounting from injected faults (all-zero when the
    /// run was fault-free).
    pub faults: FaultStats,
    /// Adaptive-controller summary (`None` when the run used a static
    /// admission budget).
    pub controller: Option<ControllerReport>,
    /// Output-length prediction accuracy (all-zero without a predictor).
    pub prediction: PredictionStats,
    /// Per-tenant-class latency breakdown (empty — and absent from the
    /// JSON — when the workload carried no tenants).
    pub tenants: TenantBreakdown,
    /// The underlying aggregate metrics (incl. per-request latencies).
    pub metrics: RunMetrics,
}

fn pct_json(p: &Percentiles) -> Json {
    Json::obj(vec![
        ("count", Json::num(p.count as f64)),
        ("mean", Json::num(p.mean)),
        ("p50", Json::num(p.p50)),
        ("p90", Json::num(p.p90)),
        ("p99", Json::num(p.p99)),
    ])
}

fn slo_dim(x: f64) -> Json {
    if x.is_finite() {
        Json::num(x)
    } else {
        Json::Null
    }
}

impl OnlineReport {
    /// Deterministic JSON rendering (objects are BTreeMaps, so the
    /// serialization is byte-stable — the determinism suite compares
    /// these strings across runs and worker counts).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("model", Json::str(self.model.clone())),
            ("num_requests", Json::num(self.num_requests as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("offered_rps", Json::num(self.offered_rps)),
            ("makespan_s", Json::num(self.makespan)),
            ("throughput_tps", Json::num(self.throughput_tps)),
            ("ttft_s", pct_json(&self.ttft)),
            ("itl_s", pct_json(&self.itl)),
            ("e2e_s", pct_json(&self.e2e)),
            (
                "slo",
                Json::obj(vec![
                    ("ttft_s", slo_dim(self.slo.ttft)),
                    ("itl_s", slo_dim(self.slo.itl)),
                    ("e2e_s", slo_dim(self.slo.e2e)),
                ]),
            ),
            ("attainment", Json::num(self.attainment)),
            ("goodput_rps", Json::num(self.goodput_rps)),
            ("peak_queue_depth", Json::num(self.peak_queue_depth as f64)),
            ("peak_kv_usage", Json::num(self.peak_kv_usage)),
            ("preemptions", Json::num(self.preemptions as f64)),
            ("swap_outs", Json::num(self.swap_outs as f64)),
            ("prefix_hit_rate", Json::num(self.prefix_hit_rate)),
            ("steps", Json::num(self.steps as f64)),
            ("faults", self.faults.to_json()),
            (
                "controller",
                match &self.controller {
                    Some(c) => c.to_json(),
                    None => Json::Null,
                },
            ),
            ("prediction", self.prediction.to_json()),
        ];
        // Key-absent (not null) when no tenants ran: a single-tenant
        // report stays byte-identical to the pre-tenant format.
        if let Some(t) = self.tenants.to_json() {
            pairs.push(("tenants", t));
        }
        Json::obj(pairs)
    }
}

/// Long-run offered load of a workload (req/s).
pub fn offered_rps(cfg: &WorkloadConfig, last_arrival: f64) -> f64 {
    match &cfg.arrivals {
        ArrivalPattern::Poisson { rate } | ArrivalPattern::Bursty { rate, .. } => *rate,
        ArrivalPattern::AllAtOnce => 0.0,
        ArrivalPattern::Trace(_) => {
            if last_arrival > 0.0 {
                cfg.num_requests as f64 / last_arrival
            } else {
                0.0
            }
        }
    }
}

/// Run one arrival-driven serving experiment in virtual time.
pub fn run_online(cfg: &OnlineConfig) -> Result<OnlineReport> {
    // The engine config's predictor flows into the workload unless the
    // workload already carries its own (single CLI knob, both drivers).
    let mut workload = cfg.workload.clone();
    if workload.predictor.is_none() {
        workload.predictor = cfg.engine.predictor;
    }
    let reqs = generate(&workload);
    let last_arrival = reqs.last().map(|r| r.arrival).unwrap_or(0.0);
    let mut engine = cfg.engine.build_engine();
    engine.submit(&reqs);

    // Stream TTFT/ITL/E2E as sequences finish; the SLO grading itself
    // is single-sourced in `RunMetrics::{attainment, goodput_rps}` over
    // the same per-request records, so the streamed summaries and the
    // graded report can never diverge.
    let mut ttft = StreamingSummary::new();
    let mut itl = StreamingSummary::new();
    let mut e2e = StreamingSummary::new();
    let mut tenants = TenantBreakdown::new();
    let mut peak_queue = 0usize;
    while engine.has_work() {
        engine.step()?;
        peak_queue = peak_queue.max(engine.waiting_count());
        for f in engine.take_finished() {
            let lat = RequestLatency {
                id: f.id,
                arrival: f.arrival,
                ttft: f.first_token_at - f.arrival,
                itl: f.itl(),
                e2e: f.finished_at - f.arrival,
                output_tokens: f.generated,
            };
            ttft.observe(lat.ttft);
            e2e.observe(lat.e2e);
            if let Some(i) = lat.itl {
                itl.observe(i);
            }
            if let Some(t) = f.tenant {
                tenants.observe(t.class, t.weight, &lat);
            }
        }
    }
    let report = engine.finish();
    // The streamed summaries (FinishedSeq-derived) and the collector's
    // per-request records (RequestTiming-derived) are two views of the
    // same clock values; pin them to each other so the definitions can
    // never silently diverge.
    debug_assert_eq!(ttft.finalize(), report.metrics.ttft_percentiles());
    debug_assert_eq!(itl.finalize(), report.metrics.itl_percentiles());
    debug_assert_eq!(e2e.finalize(), report.metrics.e2e_percentiles());
    let makespan = report.metrics.makespan;
    let attainment = report.metrics.attainment(&cfg.slo);
    let goodput_rps = report.metrics.goodput_rps(&cfg.slo);
    Ok(OnlineReport {
        model: cfg.engine.model.name.clone(),
        num_requests: reqs.len(),
        completed: report.metrics.completed,
        offered_rps: offered_rps(&cfg.workload, last_arrival),
        makespan,
        throughput_tps: report.metrics.throughput_tps,
        ttft: ttft.finalize(),
        itl: itl.finalize(),
        e2e: e2e.finalize(),
        slo: cfg.slo,
        attainment,
        goodput_rps,
        peak_queue_depth: peak_queue,
        peak_kv_usage: report.peak_kv_usage,
        preemptions: report.preemptions,
        swap_outs: report.swap_outs,
        prefix_hit_rate: report.prefix_cache.hit_rate(),
        steps: report.steps,
        faults: report.faults.clone(),
        controller: report.controller.clone(),
        prediction: report.prediction,
        tenants,
        metrics: report.metrics,
    })
}

/// Sweep Poisson offered rates over independent *single-engine* runs
/// (no replica contention — the figure frontier instead goes through
/// `bca::planner::measure_point` for MPS-contended points). Rates fan
/// out across scoped threads and come back in input order, so
/// downstream consumers stay deterministic.
pub fn sweep_rates(base: &OnlineConfig, rates: &[f64]) -> Result<Vec<(f64, OnlineReport)>> {
    let reports = crate::util::par::par_map(rates, |&rate| {
        let mut cfg = base.clone();
        cfg.workload.arrivals = ArrivalPattern::Poisson { rate };
        run_online(&cfg)
    });
    rates
        .iter()
        .zip(reports)
        .map(|(&r, rep)| Ok((r, rep?)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::spec::ModelSpec;

    fn base_engine(max_seqs: usize) -> OfflineConfig {
        OfflineConfig::new(ModelSpec::opt_1_3b(), max_seqs)
    }

    /// Offline capacity (req/s) used to scale test rates.
    fn capacity_rps(max_seqs: usize, n: usize) -> f64 {
        let mut cfg = base_engine(max_seqs);
        cfg.num_requests = n;
        cfg.input_len = 64;
        cfg.output_len = 16;
        let r = cfg.run().unwrap();
        r.metrics.completed as f64 / r.metrics.makespan
    }

    fn online_cfg(max_seqs: usize, n: usize, rate: f64) -> OnlineConfig {
        let mut cfg = OnlineConfig::poisson(base_engine(max_seqs), n, rate, 3);
        cfg.workload.lengths = crate::workload::LengthDistribution::Fixed {
            input: 64,
            output: 16,
        };
        cfg
    }

    #[test]
    fn light_load_meets_unconstrained_slo_and_tracks_offered_rate() {
        let cap = capacity_rps(8, 32);
        let rate = 0.2 * cap;
        let rep = run_online(&online_cfg(8, 40, rate)).unwrap();
        assert_eq!(rep.completed, 40);
        assert!((rep.attainment - 1.0).abs() < 1e-12); // unconstrained SLO
        // Goodput tracks the offered rate (the bound is loose because
        // the seeded arrival span of a finite trace fluctuates around
        // num_requests / rate).
        assert!(rep.goodput_rps <= rate * 1.6, "{} vs {rate}", rep.goodput_rps);
        assert!(rep.goodput_rps > 0.5 * rate, "{} vs {rate}", rep.goodput_rps);
        assert!(rep.ttft.p50 > 0.0 && rep.e2e.p99 >= rep.e2e.p50);
        assert!(rep.itl.count > 0);
    }

    #[test]
    fn overload_saturates_goodput_below_offered_rate() {
        let cap = capacity_rps(8, 32);
        let rep = run_online(&online_cfg(8, 64, 50.0 * cap)).unwrap();
        assert_eq!(rep.completed, 64);
        // Service-bound: goodput lands near capacity, far below offered.
        assert!(
            rep.goodput_rps < 0.2 * rep.offered_rps,
            "goodput {} offered {}",
            rep.goodput_rps,
            rep.offered_rps
        );
        // The backlog actually built up.
        assert!(rep.peak_queue_depth > 8, "{}", rep.peak_queue_depth);
    }

    #[test]
    fn impossible_slo_gives_zero_goodput() {
        let cap = capacity_rps(4, 16);
        let mut cfg = online_cfg(4, 16, 0.5 * cap);
        cfg.slo = Slo::itl_only(1e-12);
        let rep = run_online(&cfg).unwrap();
        // Every request decodes >= 2 tokens, so all miss the ITL bound.
        assert_eq!(rep.attainment, 0.0);
        assert_eq!(rep.goodput_rps, 0.0);
        // Percentiles are unaffected by the SLO.
        assert!(rep.itl.p50 > 0.0);
    }

    #[test]
    fn report_is_deterministic_per_seed() {
        let cfg = online_cfg(8, 48, 20.0);
        let a = run_online(&cfg).unwrap().to_json().to_string();
        let b = run_online(&cfg).unwrap().to_json().to_string();
        assert_eq!(a, b);
        let mut other = cfg.clone();
        other.workload.seed = 4;
        let c = run_online(&other).unwrap().to_json().to_string();
        assert_ne!(a, c);
    }

    #[test]
    fn controller_and_prediction_surface_in_the_report() {
        let mut cfg = online_cfg(8, 24, 20.0);
        cfg.engine.controller = Some(crate::bca::controller::ControllerConfig::new(0.05));
        cfg.engine.predictor = Some(crate::workload::PredictorConfig::default());
        let rep = run_online(&cfg).unwrap();
        let c = rep.controller.as_ref().expect("controller report missing");
        assert!(c.decisions > 0, "no decisions over a >1s run");
        // Every generated request carried a prediction; all retired.
        assert_eq!(rep.prediction.predicted_requests, rep.completed);
        let j = rep.to_json().to_string();
        assert!(j.contains("\"controller\"") && j.contains("\"prediction\""));
        // A static run renders controller as null but keeps the key.
        let plain = run_online(&online_cfg(8, 8, 20.0)).unwrap();
        assert!(plain.controller.is_none());
        assert!(plain.to_json().to_string().contains("\"controller\":null"));
    }

    #[test]
    fn tenant_sections_are_absent_without_tenants_and_additive_with_them() {
        let cfg = online_cfg(8, 24, 20.0);
        let plain = run_online(&cfg).unwrap();
        assert!(plain.tenants.is_empty());
        let plain_json = plain.to_json();
        assert!(plain_json.get("tenants").is_none());

        let mut tenanted_cfg = cfg.clone();
        tenanted_cfg.workload.tenants = Some(crate::workload::TenantsConfig::weighted(&[1, 2]));
        let rep = run_online(&tenanted_cfg).unwrap();
        let s = rep.tenants.finalize();
        assert_eq!(s.len(), 2);
        assert_eq!(s.iter().map(|c| c.completed).sum::<usize>(), rep.completed);
        assert_eq!((s[0].class, s[1].class), (0, 1));
        assert_eq!((s[0].weight, s[1].weight), (1, 2));

        // Tenant tags alone (fair_share off) must not perturb the run:
        // the tenanted report is the plain report plus ONLY the
        // "tenants" key.
        let mut tagged = rep.to_json().as_obj().unwrap().clone();
        assert!(tagged.remove("tenants").is_some());
        assert_eq!(Json::Obj(tagged), plain_json);
    }

    #[test]
    fn sweep_rates_preserves_order_and_offered_rates() {
        let base = online_cfg(8, 24, 1.0);
        let rates = [5.0, 10.0, 20.0];
        let runs = sweep_rates(&base, &rates).unwrap();
        assert_eq!(runs.len(), 3);
        for ((r, rep), want) in runs.iter().zip(rates) {
            assert_eq!(*r, want);
            assert_eq!(rep.offered_rps, want);
            assert_eq!(rep.completed, 24);
        }
    }
}
