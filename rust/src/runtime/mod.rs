//! PJRT runtime: load the AOT'd HLO artifacts and execute them from the
//! request path — python never runs here.
//!
//! The executor (`backend`/`weights`) needs the `xla` crate, which is
//! outside the offline vendor set, so both modules are gated behind the
//! off-by-default `pjrt` cargo feature; the [`manifest`] schema and the
//! artifact-discovery helpers below stay available in every build.
//!
//! The bridge follows /opt/xla-example/load_hlo: HLO **text** is the
//! interchange format (`HloModuleProto::from_text_file` reassigns the
//! 64-bit instruction ids jax >= 0.5 emits, which the crate's
//! xla_extension 0.5.1 would reject in proto form), compiled once per
//! (kind, batch-bucket) on the PJRT CPU client.
//!
//! State strategy: model weights are loaded once from
//! `artifacts/weights.bin` into host literals and passed to every
//! execute (CPU-to-CPU copies); the paged KV caches round-trip through
//! the executable's outputs — the tuple result is decomposed and the
//! cache literals are threaded into the next step, so the rust side
//! stays the single owner of cache state.

#[cfg(feature = "pjrt")]
pub mod backend;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod weights;

#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
pub use manifest::{ExecKind, ExecSpec, Manifest, TinyModelCfg};

/// Default artifacts directory (built by `make artifacts`).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(
        std::env::var("MEMGAP_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string()),
    )
}

/// True if the AOT artifacts exist (integration tests skip otherwise).
pub fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}
