//! `PjrtBackend` — real execution of the AOT'd tiny model on the PJRT
//! CPU client, implementing the same [`Backend`] trait the simulator
//! does, so the whole coordinator stack (scheduler, KV manager, router,
//! server) runs unchanged on real numerics.
//!
//! Bucketing: each (kind, batch[, seq]) pair was compiled ahead of time
//! (`aot.py`); a step batch is padded up to the smallest bucket that
//! fits. Padded rows follow the contract in `python/compile/model.py`:
//! token 0, context_len 1, block table all-zeros, slot 0 (the reserved
//! dummy block), so they cannot disturb real rows — asserted by
//! `python/tests/test_model.py::test_padded_batch_rows_do_not_disturb_real_rows`
//! and re-asserted end-to-end in `rust/tests/integration_pjrt.rs`.
//!
//! Weights are loaded once into host literals and passed by reference
//! to every execute (PJRT copies host->"device" internally on CPU); the
//! KV caches round-trip through the output tuple so rust owns state.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::{Backend, StepBatch, StepOutput};
use crate::models::spec::{FfnKind, ModelSpec};
use crate::runtime::manifest::{ExecSpec, Manifest};
use crate::runtime::weights::load_weight_literals;

/// Real-execution backend over compiled HLO buckets.
pub struct PjrtBackend {
    pub manifest: Manifest,
    spec: ModelSpec,
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    weights: Vec<xla::Literal>,
    k_cache: xla::Literal,
    v_cache: xla::Literal,
    /// Cumulative wall time spent inside execute() (perf accounting).
    pub exec_time_s: f64,
    pub exec_calls: u64,
}

/// Result of one raw executable run, before argmax.
struct RawStep {
    logits: xla::Literal,
    k_cache: xla::Literal,
    v_cache: xla::Literal,
    elapsed: f64,
}

fn cache_dims(m: &crate::runtime::manifest::TinyModelCfg) -> [usize; 4] {
    [m.n_layers, m.n_heads, m.num_slots, m.head_dim]
}

impl PjrtBackend {
    /// Load artifacts from `dir` and compile every bucket eagerly.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut executables = HashMap::new();
        for e in &manifest.executables {
            let path = manifest.dir.join(&e.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|err| anyhow!("parsing {}: {err:?}", e.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|err| anyhow!("compiling {}: {err:?}", e.file))?;
            executables.insert(e.file.clone(), exe);
        }
        let weights = load_weight_literals(&manifest).context("loading weights")?;
        let dims = cache_dims(&manifest.model);
        // CreateFromShape zero-fills — block 0 starts clean.
        let k_cache = xla::Literal::create_from_shape(xla::PrimitiveType::F32, &dims);
        let v_cache = xla::Literal::create_from_shape(xla::PrimitiveType::F32, &dims);
        let m = &manifest.model;
        let spec = ModelSpec {
            name: m.name.clone(),
            n_layers: m.n_layers,
            d_model: m.d_model,
            n_heads: m.n_heads,
            n_kv_heads: m.n_heads,
            d_ffn: 4 * m.d_model,
            vocab: m.vocab_size,
            max_seq: m.max_seq,
            ffn: FfnKind::Relu,
            dtype_bytes: 4,
        };
        Ok(Self {
            manifest,
            spec,
            client,
            executables,
            weights,
            k_cache,
            v_cache,
            exec_time_s: 0.0,
            exec_calls: 0,
        })
    }

    /// KV geometry for the engine config: (num_blocks, block_size,
    /// max_blocks_per_seq).
    pub fn kv_geometry(&self) -> (usize, usize, usize) {
        let m = &self.manifest.model;
        (m.num_blocks, m.block_size, m.max_blocks_per_seq)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Reset KV cache state (fresh serving session).
    pub fn reset_cache(&mut self) {
        let dims = cache_dims(&self.manifest.model);
        self.k_cache = xla::Literal::create_from_shape(xla::PrimitiveType::F32, &dims);
        self.v_cache = xla::Literal::create_from_shape(xla::PrimitiveType::F32, &dims);
    }

    fn i32_lit(vals: &[i32], dims: &[i64]) -> Result<xla::Literal> {
        xla::Literal::vec1(vals)
            .reshape(dims)
            .map_err(|e| anyhow!("literal reshape: {e:?}"))
    }

    /// Execute one bucket with `step_inputs` (the per-step literals) in
    /// front of the cache + weight literals; unpack the 3-tuple.
    fn execute_raw(
        executables: &HashMap<String, xla::PjRtLoadedExecutable>,
        weights: &[xla::Literal],
        k_cache: &xla::Literal,
        v_cache: &xla::Literal,
        bucket: &ExecSpec,
        step_inputs: &[&xla::Literal],
    ) -> Result<RawStep> {
        let exe = executables
            .get(&bucket.file)
            .ok_or_else(|| anyhow!("unknown executable {}", bucket.file))?;
        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(step_inputs.len() + 2 + weights.len());
        inputs.extend_from_slice(step_inputs);
        inputs.push(k_cache);
        inputs.push(v_cache);
        inputs.extend(weights.iter());
        if inputs.len() != bucket.inputs.len() {
            bail!(
                "{}: built {} inputs, manifest expects {}",
                bucket.file,
                inputs.len(),
                bucket.inputs.len()
            );
        }
        let t0 = Instant::now();
        let result = exe
            .execute::<&xla::Literal>(&inputs)
            .map_err(|e| anyhow!("execute {}: {e:?}", bucket.file))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let elapsed = t0.elapsed().as_secs_f64();
        let mut parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untuple result: {e:?}"))?;
        if parts.len() != 3 {
            bail!(
                "expected (logits, k_cache, v_cache), got {} parts",
                parts.len()
            );
        }
        let v_cache = parts.pop().unwrap();
        let k_cache = parts.pop().unwrap();
        let logits = parts.pop().unwrap();
        Ok(RawStep {
            logits,
            k_cache,
            v_cache,
            elapsed,
        })
    }

    /// Greedy argmax over the first `real_rows` logit rows.
    fn argmax_rows(logits: &xla::Literal, real_rows: usize) -> Result<Vec<i32>> {
        let shape = logits
            .array_shape()
            .map_err(|e| anyhow!("logits shape: {e:?}"))?;
        let vocab = *shape.dims().last().unwrap() as usize;
        let vals = logits
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits to_vec: {e:?}"))?;
        let mut next = Vec::with_capacity(real_rows);
        for r in 0..real_rows {
            let row = &vals[r * vocab..(r + 1) * vocab];
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for (i, &v) in row.iter().enumerate() {
                if v > best_v {
                    best_v = v;
                    best = i;
                }
            }
            next.push(best as i32);
        }
        Ok(next)
    }

    fn finish_step(&mut self, raw: RawStep, real_rows: usize) -> Result<StepOutput> {
        self.k_cache = raw.k_cache;
        self.v_cache = raw.v_cache;
        self.exec_time_s += raw.elapsed;
        self.exec_calls += 1;
        Ok(StepOutput {
            next_tokens: Self::argmax_rows(&raw.logits, real_rows)?,
            gpu_time: raw.elapsed,
            cpu_gap: 0.0, // host time is real wall time here
            summary: None,
            sim: None,
        })
    }
}

impl Backend for PjrtBackend {
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn max_batch(&self) -> usize {
        self.manifest.max_decode_batch()
    }

    fn prefill(&mut self, batch: &StepBatch) -> Result<StepOutput> {
        let n = batch.len();
        let max_len = batch
            .entries
            .iter()
            .map(|e| e.tokens.len())
            .max()
            .unwrap_or(1);
        let bucket = self
            .manifest
            .prefill_bucket(n, max_len)
            .ok_or_else(|| {
                anyhow!(
                    "no prefill bucket for batch {n} x seq {max_len} \
                     (prompts longer than {} must be split upstream)",
                    self.manifest.max_prefill_seq()
                )
            })?
            .clone();
        let b = bucket.batch;
        let s = bucket.seq.expect("prefill bucket has seq");

        let mut tokens = vec![0i32; b * s];
        let mut prompt_lens = vec![1i32; b];
        let mut slots = vec![0i32; b * s];
        for (i, e) in batch.entries.iter().enumerate() {
            prompt_lens[i] = e.tokens.len() as i32;
            for (j, &t) in e.tokens.iter().enumerate() {
                tokens[i * s + j] = t;
            }
            for (j, &sl) in e.slot_mapping.iter().enumerate() {
                slots[i * s + j] = sl as i32;
            }
        }
        let tokens_l = Self::i32_lit(&tokens, &[b as i64, s as i64])?;
        let lens_l = Self::i32_lit(&prompt_lens, &[b as i64])?;
        let slots_l = Self::i32_lit(&slots, &[b as i64, s as i64])?;

        let raw = Self::execute_raw(
            &self.executables,
            &self.weights,
            &self.k_cache,
            &self.v_cache,
            &bucket,
            &[&tokens_l, &lens_l, &slots_l],
        )?;
        self.finish_step(raw, n)
    }

    fn decode(&mut self, batch: &StepBatch) -> Result<StepOutput> {
        let n = batch.len();
        let bucket = self
            .manifest
            .decode_bucket(n)
            .ok_or_else(|| anyhow!("no decode bucket fits batch {n}"))?
            .clone();
        let b = bucket.batch;
        let mb = self.manifest.model.max_blocks_per_seq;

        let mut tokens = vec![0i32; b];
        let mut ctx = vec![1i32; b];
        let mut slots = vec![0i32; b];
        let mut bt = vec![0i32; b * mb];
        for (i, e) in batch.entries.iter().enumerate() {
            tokens[i] = *e.tokens.last().unwrap_or(&0);
            ctx[i] = e.context_len as i32;
            slots[i] = *e.slot_mapping.last().unwrap_or(&0) as i32;
            if e.block_table.len() > mb {
                bail!("sequence {} exceeds max_blocks_per_seq {mb}", e.seq);
            }
            for (j, &blk) in e.block_table.iter().enumerate() {
                bt[i * mb + j] = blk as i32;
            }
        }
        let tokens_l = Self::i32_lit(&tokens, &[b as i64])?;
        let bt_l = Self::i32_lit(&bt, &[b as i64, mb as i64])?;
        let ctx_l = Self::i32_lit(&ctx, &[b as i64])?;
        let slots_l = Self::i32_lit(&slots, &[b as i64])?;

        let raw = Self::execute_raw(
            &self.executables,
            &self.weights,
            &self.k_cache,
            &self.v_cache,
            &bucket,
            &[&tokens_l, &bt_l, &ctx_l, &slots_l],
        )?;
        self.finish_step(raw, n)
    }
}
