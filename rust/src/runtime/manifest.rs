//! `artifacts/manifest.json` — the contract between `python/compile`
//! and the rust runtime (schema emitted by `aot.py`, format_version 1).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// The tiny model's configuration (mirrors `python ModelConfig`).
#[derive(Debug, Clone)]
pub struct TinyModelCfg {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub vocab_size: usize,
    pub max_seq: usize,
    pub block_size: usize,
    pub num_blocks: usize,
    pub max_blocks_per_seq: usize,
    pub num_slots: usize,
    pub param_count: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ExecKind {
    Decode,
    Prefill,
}

/// One compiled executable bucket.
#[derive(Debug, Clone)]
pub struct ExecSpec {
    pub kind: ExecKind,
    pub batch: usize,
    /// Padded sequence length (prefill only).
    pub seq: Option<usize>,
    pub file: String,
    pub inputs: Vec<String>,
}

/// One weight tensor's location in weights.bin.
#[derive(Debug, Clone)]
pub struct TensorInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_bytes: usize,
    pub size_bytes: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: TinyModelCfg,
    pub seed: u64,
    pub weights_file: String,
    pub tensors: Vec<TensorInfo>,
    pub executables: Vec<ExecSpec>,
}

fn req_usize(obj: &Json, key: &str) -> Result<usize> {
    obj.get(key)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| anyhow!("manifest: missing numeric field '{key}'"))
}

fn req_str(obj: &Json, key: &str) -> Result<String> {
    Ok(obj
        .get(key)
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("manifest: missing string field '{key}'"))?
        .to_string())
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        if j.get("format_version").and_then(|v| v.as_u64()) != Some(1) {
            bail!("unsupported manifest format_version");
        }
        let m = j.get("model").ok_or_else(|| anyhow!("missing model"))?;
        let model = TinyModelCfg {
            name: req_str(m, "name")?,
            n_layers: req_usize(m, "n_layers")?,
            d_model: req_usize(m, "d_model")?,
            n_heads: req_usize(m, "n_heads")?,
            head_dim: req_usize(m, "head_dim")?,
            vocab_size: req_usize(m, "vocab_size")?,
            max_seq: req_usize(m, "max_seq")?,
            block_size: req_usize(m, "block_size")?,
            num_blocks: req_usize(m, "num_blocks")?,
            max_blocks_per_seq: req_usize(m, "max_blocks_per_seq")?,
            num_slots: req_usize(m, "num_slots")?,
            param_count: req_usize(m, "param_count")? as u64,
        };
        let w = j.get("weights").ok_or_else(|| anyhow!("missing weights"))?;
        let tensors = w
            .get("tensors")
            .and_then(|t| t.as_arr())
            .ok_or_else(|| anyhow!("missing weights.tensors"))?
            .iter()
            .map(|t| {
                Ok(TensorInfo {
                    name: req_str(t, "name")?,
                    shape: t
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .ok_or_else(|| anyhow!("tensor missing shape"))?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    offset_bytes: req_usize(t, "offset_bytes")?,
                    size_bytes: req_usize(t, "size_bytes")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let executables = j
            .get("executables")
            .and_then(|e| e.as_arr())
            .ok_or_else(|| anyhow!("missing executables"))?
            .iter()
            .map(|e| {
                let kind = match req_str(e, "kind")?.as_str() {
                    "decode" => ExecKind::Decode,
                    "prefill" => ExecKind::Prefill,
                    k => bail!("unknown executable kind '{k}'"),
                };
                Ok(ExecSpec {
                    kind,
                    batch: req_usize(e, "batch")?,
                    seq: e.get("seq").and_then(|s| s.as_usize()),
                    file: req_str(e, "file")?,
                    inputs: e
                        .get("inputs")
                        .and_then(|i| i.as_arr())
                        .map(|a| {
                            a.iter()
                                .filter_map(|x| x.as_str().map(String::from))
                                .collect()
                        })
                        .unwrap_or_default(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            model,
            seed: j.get("seed").and_then(|s| s.as_u64()).unwrap_or(0),
            weights_file: req_str(w, "file")?,
            tensors,
            executables,
        })
    }

    /// Smallest decode bucket with capacity >= `batch`.
    pub fn decode_bucket(&self, batch: usize) -> Option<&ExecSpec> {
        self.executables
            .iter()
            .filter(|e| e.kind == ExecKind::Decode && e.batch >= batch)
            .min_by_key(|e| e.batch)
    }

    /// Smallest prefill bucket fitting `batch` prompts of length <= `seq`.
    pub fn prefill_bucket(&self, batch: usize, seq: usize) -> Option<&ExecSpec> {
        self.executables
            .iter()
            .filter(|e| {
                e.kind == ExecKind::Prefill && e.batch >= batch && e.seq.unwrap_or(0) >= seq
            })
            .min_by_key(|e| (e.batch, e.seq.unwrap_or(0)))
    }

    pub fn max_decode_batch(&self) -> usize {
        self.executables
            .iter()
            .filter(|e| e.kind == ExecKind::Decode)
            .map(|e| e.batch)
            .max()
            .unwrap_or(0)
    }

    pub fn max_prefill_seq(&self) -> usize {
        self.executables
            .iter()
            .filter(|e| e.kind == ExecKind::Prefill)
            .filter_map(|e| e.seq)
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_fixture(dir: &Path) {
        let manifest = r#"{
          "format_version": 1,
          "model": {"name": "micro-opt", "n_layers": 2, "d_model": 64,
                    "n_heads": 4, "head_dim": 16, "vocab_size": 512,
                    "ffn_mult": 4, "max_seq": 128, "block_size": 8,
                    "num_blocks": 64, "max_blocks_per_seq": 8,
                    "num_slots": 512, "d_ffn": 256, "param_count": 1000},
          "seed": 3,
          "weights": {"file": "weights.bin",
                      "tensors": [{"name": "embed", "shape": [512, 64],
                                   "dtype": "f32", "offset_bytes": 0,
                                   "size_bytes": 131072}]},
          "executables": [
            {"kind": "decode", "batch": 1, "file": "decode_b1.hlo.txt",
             "inputs": ["tokens"], "outputs": ["logits"], "sha256": "x"},
            {"kind": "decode", "batch": 4, "file": "decode_b4.hlo.txt",
             "inputs": ["tokens"], "outputs": ["logits"], "sha256": "x"},
            {"kind": "prefill", "batch": 2, "seq": 32,
             "file": "prefill_b2_s32.hlo.txt", "inputs": ["tokens"],
             "outputs": ["logits"], "sha256": "x"}
          ]
        }"#;
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(manifest.as_bytes()).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "memgap-manifest-{tag}-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn loads_and_indexes_buckets() {
        let dir = tmpdir("load");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.name, "micro-opt");
        assert_eq!(m.model.num_slots, 512);
        assert_eq!(m.tensors[0].shape, vec![512, 64]);
        assert_eq!(m.decode_bucket(1).unwrap().batch, 1);
        assert_eq!(m.decode_bucket(2).unwrap().batch, 4);
        assert_eq!(m.decode_bucket(3).unwrap().batch, 4);
        assert!(m.decode_bucket(5).is_none());
        assert_eq!(m.prefill_bucket(1, 20).unwrap().seq, Some(32));
        assert!(m.prefill_bucket(1, 64).is_none());
        assert_eq!(m.max_decode_batch(), 4);
        assert_eq!(m.max_prefill_seq(), 32);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_an_error() {
        let dir = tmpdir("missing");
        std::fs::remove_file(dir.join("manifest.json")).ok();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
