//! Load `artifacts/weights.bin` into XLA literals, in the exact
//! WEIGHT_ORDER the executables expect as trailing parameters.

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;

/// Read the weight file and materialize one f32 literal per tensor.
pub fn load_weight_literals(manifest: &Manifest) -> Result<Vec<xla::Literal>> {
    let path = manifest.dir.join(&manifest.weights_file);
    let raw = std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
    let expected: usize = manifest.tensors.iter().map(|t| t.size_bytes).sum();
    if raw.len() != expected {
        bail!(
            "weights.bin is {} bytes, manifest expects {expected}",
            raw.len()
        );
    }
    let mut out = Vec::with_capacity(manifest.tensors.len());
    for t in &manifest.tensors {
        let bytes = &raw[t.offset_bytes..t.offset_bytes + t.size_bytes];
        let n = t.size_bytes / 4;
        let mut floats = vec![0f32; n];
        // weights.bin is little-endian f32 (written by numpy on x86).
        for (i, c) in bytes.chunks_exact(4).enumerate() {
            floats[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        let numel: usize = t.shape.iter().product();
        if numel != n {
            bail!("tensor {}: shape {:?} != {} elements", t.name, t.shape, n);
        }
        let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&floats)
            .reshape(&dims)
            .with_context(|| format!("reshaping {}", t.name))?;
        out.push(lit);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::TensorInfo;
    use std::io::Write;

    #[test]
    fn roundtrips_f32_tensors() {
        let dir = std::env::temp_dir().join(format!("memgap-weights-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let data: Vec<f32> = (0..12).map(|i| i as f32 * 0.5).collect();
        let raw: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::File::create(dir.join("weights.bin"))
            .unwrap()
            .write_all(&raw)
            .unwrap();
        let manifest = Manifest {
            dir: dir.clone(),
            model: crate::runtime::manifest::TinyModelCfg {
                name: "t".into(),
                n_layers: 1,
                d_model: 4,
                n_heads: 1,
                head_dim: 4,
                vocab_size: 3,
                max_seq: 8,
                block_size: 4,
                num_blocks: 4,
                max_blocks_per_seq: 2,
                num_slots: 16,
                param_count: 12,
            },
            seed: 0,
            weights_file: "weights.bin".into(),
            tensors: vec![TensorInfo {
                name: "embed".into(),
                shape: vec![3, 4],
                offset_bytes: 0,
                size_bytes: 48,
            }],
            executables: vec![],
        };
        let lits = load_weight_literals(&manifest).unwrap();
        assert_eq!(lits.len(), 1);
        let back = lits[0].to_vec::<f32>().unwrap();
        assert_eq!(back, data);
        let shape = lits[0].array_shape().unwrap();
        assert_eq!(shape.dims(), &[3, 4]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn size_mismatch_rejected() {
        let dir = std::env::temp_dir().join(format!("memgap-weights2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("weights.bin"), [0u8; 16]).unwrap();
        let manifest = Manifest {
            dir: dir.clone(),
            model: crate::runtime::manifest::TinyModelCfg {
                name: "t".into(),
                n_layers: 1,
                d_model: 4,
                n_heads: 1,
                head_dim: 4,
                vocab_size: 3,
                max_seq: 8,
                block_size: 4,
                num_blocks: 4,
                max_blocks_per_seq: 2,
                num_slots: 16,
                param_count: 12,
            },
            seed: 0,
            weights_file: "weights.bin".into(),
            tensors: vec![TensorInfo {
                name: "embed".into(),
                shape: vec![3, 4],
                offset_bytes: 0,
                size_bytes: 48,
            }],
            executables: vec![],
        };
        assert!(load_weight_literals(&manifest).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
