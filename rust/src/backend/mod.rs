//! Execution backends: one engine, two ways to run a step.
//!
//! The coordinator (scheduler, KV manager, router, metrics) is identical
//! over both backends — that is the point of the design: the *policies*
//! the paper studies are exercised by the same code whether steps are
//! simulated on the H100 model or actually executed on the PJRT CPU
//! client.
//!
//! - [`SimBackend`]  — every paper table/figure: steps are costed by
//!   `gpusim` and return the full kernel-level detail.
//! - `runtime::PjrtBackend` (behind the `pjrt` feature) — the real
//!   thing: loads the AOT'd HLO artifacts and computes actual logits
//!   (end-to-end example + integration tests).

use anyhow::Result;

use crate::gpusim::kernels::{CtxAggregates, PromptAggregates};
use crate::gpusim::plan::{DecodeCostModel, PlanScratch, StepPlan, StepSummary};
use crate::gpusim::step::StepSim;
use crate::gpusim::{self, GpuSpec};
use crate::kvcache::SeqId;
use crate::models::spec::{AttentionBackendKind, ModelSpec};

/// One sequence's slice of a step batch.
#[derive(Debug, Clone, Default)]
pub struct SeqBatchEntry {
    pub seq: SeqId,
    /// Token ids this step feeds: the whole prompt for prefill, the
    /// single last token for decode. (The simulator only uses lengths.)
    pub tokens: Vec<i32>,
    /// Tokens in context *including* the ones fed this step.
    pub context_len: usize,
    /// Physical KV block table (unpadded).
    pub block_table: Vec<u32>,
    /// Physical slot for each fed token's K/V.
    pub slot_mapping: Vec<u32>,
}

/// A batch of sequences for one engine step.
#[derive(Debug, Clone, Default)]
pub struct StepBatch {
    pub entries: Vec<SeqBatchEntry>,
}

impl StepBatch {
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn context_lens(&self) -> Vec<usize> {
        self.entries.iter().map(|e| e.context_len).collect()
    }

    pub fn fed_tokens(&self) -> usize {
        self.entries.iter().map(|e| e.tokens.len()).sum()
    }
}

/// Result of one backend step.
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// Next token per batch entry (greedy argmax).
    pub next_tokens: Vec<i32>,
    /// GPU burst duration in seconds (simulated or wall-measured).
    pub gpu_time: f64,
    /// Host-side gap in seconds (simulated; 0 for real execution,
    /// where host time is part of the wall clock).
    pub cpu_gap: f64,
    /// Heap-free step totals, present whenever the step was simulated
    /// (both recording and summary mode; None on PJRT).
    pub summary: Option<StepSummary>,
    /// Full kernel-level detail when simulated *with recording on*
    /// (None on PJRT and in summary mode — see [`Backend::set_record`]).
    pub sim: Option<StepSim>,
}

/// Abstract step executor the engine drives.
pub trait Backend {
    fn spec(&self) -> &ModelSpec;

    /// Largest batch a single call may carry (PJRT: largest compiled
    /// bucket; simulator: unbounded).
    fn max_batch(&self) -> usize {
        usize::MAX
    }

    /// Whether this backend reads block tables / slot mappings. The
    /// simulator only needs lengths, so the engine skips cloning the
    /// tables into every step batch (§Perf L3: ~100us/step at B=512).
    fn needs_tables(&self) -> bool {
        true
    }

    /// Toggle full kernel-level recording: with recording on, simulated
    /// steps carry a [`StepSim`]; with it off they carry only the
    /// heap-free [`StepSummary`] (the steady-state fast path). The
    /// engine forwards `EngineConfig::record_steps` here. Backends
    /// without a simulator ignore it.
    fn set_record(&mut self, _record: bool) {}

    /// Effective host<->device link bandwidth (bytes/s) for KV swap
    /// transfers. Default ~50 GB/s PCIe; the simulator reports its
    /// calibrated `GpuSpec::pcie_bw`.
    fn link_bw(&self) -> f64 {
        50.0e9
    }

    /// Seconds to move `blocks` KV blocks of `block_size` token slots
    /// each across the host link (swap preemption, either direction).
    fn swap_time(&self, blocks: usize, block_size: usize) -> f64 {
        let bytes = self
            .spec()
            .kv_bytes_per_token()
            .saturating_mul((blocks * block_size) as u64);
        bytes as f64 / self.link_bw()
    }

    /// A closed-form per-step cost model for a steady decode streak over
    /// the given context lengths, or `None` if this backend cannot price
    /// steps analytically (PJRT) or its outputs would not be bit-stable
    /// against [`Backend::decode`] (recording mode). Each
    /// [`DecodeCostModel::next_step`] must reproduce *exactly* — same
    /// floating-point result, not approximately — the `StepSummary` that
    /// `decode` would return for the batch after every context length has
    /// grown by one token per emitted step.
    fn decode_cost_model(&self, _ctx_lens: &[usize]) -> Option<DecodeCostModel> {
        None
    }

    /// The token [`Backend::decode`] would emit for `seq` at
    /// `context_len` during a steady decode streak. Fast-forward uses
    /// this to synthesize the skipped tokens; it must match what `decode`
    /// puts in `StepOutput::next_tokens` for the same entry.
    fn steady_decode_token(&self, _seq: SeqId, _context_len: usize) -> i32 {
        0
    }

    /// Process prompts and produce each sequence's first token.
    fn prefill(&mut self, batch: &StepBatch) -> Result<StepOutput>;

    /// One decode step over the running batch.
    fn decode(&mut self, batch: &StepBatch) -> Result<StepOutput>;

    /// Chunked-prefill step: decode `decodes` while processing prompt
    /// chunks of `prefills` in the same pass (Sarathi-style; used by the
    /// Table IV comparison). Backends may not support it.
    fn mixed(&mut self, _prefills: &StepBatch, _decodes: &StepBatch) -> Result<StepOutput> {
        anyhow::bail!("this backend does not support chunked prefill")
    }
}

/// Simulated backend over the analytical H100 model.
///
/// Holds a [`StepPlan`] compiled once at construction — `model` and
/// `attention` are fixed from then on — plus reusable scratch so
/// summary-mode steps allocate nothing per kernel.
#[derive(Debug, Clone)]
pub struct SimBackend {
    pub gpu: GpuSpec,
    pub model: ModelSpec,
    pub attention: AttentionBackendKind,
    pub kv_block: usize,
    plan: StepPlan,
    scratch: PlanScratch,
    record: bool,
}

impl SimBackend {
    pub fn new(gpu: GpuSpec, model: ModelSpec, attention: AttentionBackendKind) -> Self {
        let plan = StepPlan::new(model.clone(), attention);
        Self {
            gpu,
            model,
            attention,
            kv_block: 16,
            plan,
            scratch: PlanScratch::default(),
            record: true,
        }
    }

    /// A backend whose step plan is the per-rank schedule of a `tp`-way
    /// tensor-parallel engine (Megatron sharding + explicit ring
    /// collectives). `tp = 1` is bit-identical to [`SimBackend::new`].
    pub fn with_tp(
        gpu: GpuSpec,
        model: ModelSpec,
        attention: AttentionBackendKind,
        tp: usize,
    ) -> Result<Self> {
        let plan = StepPlan::with_tp(model.clone(), attention, tp)?;
        Ok(Self {
            gpu,
            model,
            attention,
            kv_block: 16,
            plan,
            scratch: PlanScratch::default(),
            record: true,
        })
    }

    /// Tensor-parallel degree of the compiled plan (1 = unsharded).
    pub fn tp(&self) -> usize {
        self.plan.tp()
    }

    /// Deterministic stand-in tokens (content is irrelevant to the sim).
    fn fake_tokens(&self, batch: &StepBatch) -> Vec<i32> {
        batch
            .entries
            .iter()
            .map(|e| ((e.seq as usize * 31 + e.context_len) % self.model.vocab) as i32)
            .collect()
    }
}

impl Backend for SimBackend {
    fn spec(&self) -> &ModelSpec {
        &self.model
    }

    fn needs_tables(&self) -> bool {
        false
    }

    fn set_record(&mut self, record: bool) {
        self.record = record;
    }

    fn link_bw(&self) -> f64 {
        self.gpu.pcie_bw
    }

    fn decode_cost_model(&self, ctx_lens: &[usize]) -> Option<DecodeCostModel> {
        if self.record {
            // Recording mode folds per-kernel durations in a different
            // order (`StepSummary::from_sim`), so the closed-form model
            // would diverge by ULPs. Decline; the engine stays stepwise.
            return None;
        }
        Some(self.plan.decode_cost_model(&self.gpu, ctx_lens, self.kv_block))
    }

    fn steady_decode_token(&self, seq: SeqId, context_len: usize) -> i32 {
        // Must match `fake_tokens` term-for-term.
        ((seq as usize * 31 + context_len) % self.model.vocab) as i32
    }

    fn prefill(&mut self, batch: &StepBatch) -> Result<StepOutput> {
        let agg =
            PromptAggregates::from_iter_lens(batch.entries.iter().map(|e| e.tokens.len()));
        if self.record {
            let sim = self.plan.prefill_sim_aggregated(&self.gpu, &agg);
            Ok(StepOutput {
                next_tokens: self.fake_tokens(batch),
                gpu_time: sim.gpu_time,
                cpu_gap: sim.cpu_gap,
                summary: Some(StepSummary::from_sim(&sim)),
                sim: Some(sim),
            })
        } else {
            let summary = self.plan.prefill_summary(&self.gpu, &agg, &mut self.scratch);
            Ok(StepOutput {
                next_tokens: self.fake_tokens(batch),
                gpu_time: summary.gpu_time,
                cpu_gap: summary.cpu_gap,
                summary: Some(summary),
                sim: None,
            })
        }
    }

    fn decode(&mut self, batch: &StepBatch) -> Result<StepOutput> {
        let agg = CtxAggregates::from_iter_lens(
            batch.entries.iter().map(|e| e.context_len),
            self.kv_block,
        );
        if self.record {
            let sim = self.plan.decode_sim_aggregated(&self.gpu, &agg);
            Ok(StepOutput {
                next_tokens: self.fake_tokens(batch),
                gpu_time: sim.gpu_time,
                cpu_gap: sim.cpu_gap,
                summary: Some(StepSummary::from_sim(&sim)),
                sim: Some(sim),
            })
        } else {
            let summary = self.plan.decode_summary(&self.gpu, &agg, &mut self.scratch);
            Ok(StepOutput {
                next_tokens: self.fake_tokens(batch),
                gpu_time: summary.gpu_time,
                cpu_gap: summary.cpu_gap,
                summary: Some(summary),
                sim: None,
            })
        }
    }

    fn mixed(&mut self, prefills: &StepBatch, decodes: &StepBatch) -> Result<StepOutput> {
        // Sarathi-style chunked prefill: one fused pass. Model it as the
        // decode step plus the prefill chunk's kernels sharing a single
        // launch train and ONE host gap (that is the point of chunking).
        let d_agg = CtxAggregates::from_iter_lens(
            decodes.entries.iter().map(|e| e.context_len),
            self.kv_block,
        );
        let p_agg =
            PromptAggregates::from_iter_lens(prefills.entries.iter().map(|e| e.tokens.len()));
        let batch = d_agg.count + p_agg.count;
        let cpu_gap = gpusim::cpu::step_gap(&self.gpu, batch);
        let mut next = self.fake_tokens(decodes);
        next.extend(self.fake_tokens(prefills));
        if self.record {
            let mut kernels = Vec::new();
            let mut gpu_time = 0.0;
            if d_agg.count > 0 {
                let sim = self.plan.decode_sim_aggregated(&self.gpu, &d_agg);
                gpu_time += sim.gpu_time;
                kernels.extend(sim.kernels);
            }
            if p_agg.count > 0 {
                let sim = self.plan.prefill_sim_aggregated(&self.gpu, &p_agg);
                gpu_time += sim.gpu_time;
                // Offset the prefill kernels after the decode ones.
                let offset = kernels
                    .last()
                    .map(|k: &gpusim::KernelExec| k.end())
                    .unwrap_or(0.0);
                kernels.extend(sim.kernels.into_iter().map(|mut k| {
                    k.start += offset;
                    k
                }));
            }
            let sim = StepSim {
                kernels,
                gpu_time,
                cpu_gap,
                batch,
            };
            Ok(StepOutput {
                next_tokens: next,
                gpu_time,
                cpu_gap,
                summary: Some(StepSummary::from_sim(&sim)),
                sim: Some(sim),
            })
        } else {
            let mut summary = StepSummary::default();
            if d_agg.count > 0 {
                summary.absorb(&self.plan.decode_summary(&self.gpu, &d_agg, &mut self.scratch));
            }
            if p_agg.count > 0 {
                summary
                    .absorb(&self.plan.prefill_summary(&self.gpu, &p_agg, &mut self.scratch));
            }
            // ONE host gap for the fused step, sized by the whole batch.
            summary.cpu_gap = cpu_gap;
            summary.batch = batch;
            Ok(StepOutput {
                next_tokens: next,
                gpu_time: summary.gpu_time,
                cpu_gap,
                summary: Some(summary),
                sim: None,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(ctxs: &[usize]) -> StepBatch {
        StepBatch {
            entries: ctxs
                .iter()
                .enumerate()
                .map(|(i, &c)| SeqBatchEntry {
                    seq: i as u64,
                    tokens: vec![0],
                    context_len: c,
                    block_table: vec![1],
                    slot_mapping: vec![0],
                })
                .collect(),
        }
    }

    fn sim() -> SimBackend {
        SimBackend::new(
            GpuSpec::h100_64g(),
            ModelSpec::opt_1_3b(),
            AttentionBackendKind::XFormers,
        )
    }

    #[test]
    fn decode_returns_one_token_per_entry() {
        let mut b = sim();
        let out = b.decode(&batch(&[100, 200, 300])).unwrap();
        assert_eq!(out.next_tokens.len(), 3);
        assert!(out.gpu_time > 0.0);
        assert!(out.cpu_gap > 0.0);
        assert!(out.sim.is_some());
    }

    #[test]
    fn fake_tokens_in_vocab_and_deterministic() {
        let mut b = sim();
        let o1 = b.decode(&batch(&[42])).unwrap();
        let o2 = b.decode(&batch(&[42])).unwrap();
        assert_eq!(o1.next_tokens, o2.next_tokens);
        assert!((o1.next_tokens[0] as usize) < b.model.vocab);
    }

    #[test]
    fn summary_mode_drops_kernel_detail_but_keeps_totals() {
        let mut rec = sim();
        let mut fast = sim();
        fast.set_record(false);
        let b = batch(&[100, 250, 400]);
        let r = rec.decode(&b).unwrap();
        let f = fast.decode(&b).unwrap();
        assert!(r.sim.is_some());
        assert!(f.sim.is_none());
        let fs = f.summary.expect("summary in fast mode");
        let rs = r.summary.expect("summary in record mode");
        assert_eq!(f.next_tokens, r.next_tokens);
        assert_eq!(fs.batch, rs.batch);
        assert_eq!(fs.num_kernels, rs.num_kernels);
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs());
        assert!(close(f.gpu_time, r.gpu_time), "{} vs {}", f.gpu_time, r.gpu_time);
        assert_eq!(f.cpu_gap, r.cpu_gap);
        assert!(close(fs.mean_dram_read_util(), rs.mean_dram_read_util()));
    }

    #[test]
    fn tp_backend_is_identity_at_tp1_and_shards_beyond() {
        let mut plain = sim();
        let mut tp1 = SimBackend::with_tp(
            GpuSpec::h100_64g(),
            ModelSpec::opt_1_3b(),
            AttentionBackendKind::XFormers,
            1,
        )
        .unwrap();
        let b = batch(&[338; 96]);
        let o = plain.decode(&b).unwrap();
        let o1 = tp1.decode(&b).unwrap();
        assert_eq!(o.gpu_time, o1.gpu_time);
        assert_eq!(o.cpu_gap, o1.cpu_gap);
        assert_eq!(o.next_tokens, o1.next_tokens);
        // tp=2: per-rank step is faster even after paying collectives,
        // but the host gap (batch-sized) is identical.
        let mut tp2 = SimBackend::with_tp(
            GpuSpec::h100_64g(),
            ModelSpec::opt_1_3b(),
            AttentionBackendKind::XFormers,
            2,
        )
        .unwrap();
        assert_eq!(tp2.tp(), 2);
        let o2 = tp2.decode(&b).unwrap();
        assert!(o2.gpu_time < o.gpu_time, "{} vs {}", o2.gpu_time, o.gpu_time);
        assert_eq!(o2.cpu_gap, o.cpu_gap);
    }

    #[test]
    fn mixed_has_single_cpu_gap() {
        let mut b = sim();
        let pre = StepBatch {
            entries: vec![SeqBatchEntry {
                seq: 9,
                tokens: vec![0; 64],
                context_len: 64,
                block_table: vec![1; 4],
                slot_mapping: vec![0; 64],
            }],
        };
        let dec = batch(&[100; 8]);
        let out = b.mixed(&pre, &dec).unwrap();
        assert_eq!(out.next_tokens.len(), 9);
        // One gap for the fused step, sized by the combined batch.
        let solo_dec = b.decode(&dec).unwrap();
        assert!(out.cpu_gap > solo_dec.cpu_gap);
        assert!(out.cpu_gap < 2.0 * solo_dec.cpu_gap + 1e-4);
    }
}
