//! Model replication on one GPU (paper §VI-B, Fig 13, Table IV) and
//! tensor-parallel group co-scheduling on a multi-GPU budget.
//!
//! With BCA freeing most of the KV allocation, multiple engine replicas
//! fit on the same device. Each replica gets an equal share of the
//! usable memory, requests are routed round-robin (the paper
//! distributes them evenly), and the replicas' CPU/GPU traces are
//! co-scheduled by the MPS processor-sharing executor (or FCFS
//! time-sharing as the baseline).
//!
//! [`run_cluster`] generalizes this to a fixed GPU budget with
//! tensor-parallel engines: a tp=k engine occupies k GPUs (one TP
//! *group*), the budget partitions into `gpus / tp` groups, and engines
//! assigned to the same group share its DRAM via the same MPS model.
//! Engines on different groups touch disjoint GPUs and never contend —
//! which is exactly why replication across GPUs beats sharding for
//! small models: it buys parallel HBM *and* parallel host loops, where
//! sharding pays collectives for parallel HBM only.
//!
//! Methodology note (documented in DESIGN.md §2): each replica's engine
//! runs against the simulator in its own virtual time producing an
//! alternating CPU-gap / GPU-burst trace; `gpusim::mps::run_shared`
//! then co-schedules those traces on one device. Per-replica slowdown
//! from contention is applied to the latency metrics; throughput comes
//! from total tokens over the shared makespan.

use anyhow::{ensure, Result};

use crate::coordinator::offline::OfflineConfig;
use crate::coordinator::router::{RoutePolicy, Router};
use crate::faults::{FaultPlan, FaultStats};
use crate::gpusim::mps::{run_shared, Segment, SharePolicy, SharedRun};
use crate::metrics::RunMetrics;
use crate::workload::Request;

/// Result of a replicated serving run.
#[derive(Debug, Clone)]
pub struct ReplicatedReport {
    pub replicas: usize,
    pub policy: SharePolicy,
    /// Total (input+output) tokens per second across replicas.
    pub throughput_tps: f64,
    /// Mean ITL across replicas, contention-stretched (seconds).
    pub mean_itl: f64,
    /// Mean E2E across replicas, contention-stretched (seconds).
    pub mean_e2e: f64,
    /// Peak KV usage per replica (fraction of the replica's pool).
    pub kv_usage: f64,
    /// Shared-run makespan (seconds).
    pub makespan: f64,
    /// Fraction of the makespan with NO GPU kernel running — the
    /// "CPU time" column of Table IV.
    pub cpu_time_frac: f64,
    /// Time-averaged aggregate DRAM demand (Table IV "DRAM read").
    pub mean_dram_util: f64,
    /// Per-replica contention stretch (shared finish / solo finish).
    pub stretch: Vec<f64>,
    /// Per-replica solo run metrics (virtual time, pre-contention);
    /// combined with `stretch` they give per-request latencies under
    /// contention — the SLO planner's percentile surface.
    pub solo_metrics: Vec<crate::metrics::RunMetrics>,
    /// Availability accounting merged across replicas, plus front-end
    /// reroutes (all-zero on a fault-free run).
    pub faults: FaultStats,
    /// The shared schedule, for Fig-13-style timelines.
    pub shared: SharedRun,
}

impl ReplicatedReport {
    /// Per-request mean ITLs across all replicas, each stretched by its
    /// replica's contention factor (single-token requests excluded).
    pub fn stretched_itls(&self) -> Vec<f64> {
        let mut out = Vec::new();
        for (m, &s) in self.solo_metrics.iter().zip(&self.stretch) {
            out.extend(m.latencies.iter().filter_map(|l| l.itl.map(|i| i * s)));
        }
        out
    }

    /// Completed requests across replicas.
    pub fn completed(&self) -> usize {
        self.solo_metrics.iter().map(|m| m.completed).sum()
    }
}

/// Run `base` replicated `n` ways under `policy` over `requests`.
///
/// `mem_fraction_each` is each replica's share of the usable memory
/// (BCA's `engine_mem_fraction`, or 1/n for an even split).
pub fn run_replicated(
    base: &OfflineConfig,
    n: usize,
    policy: SharePolicy,
    requests: &[Request],
    mem_fraction_each: f64,
) -> Result<ReplicatedReport> {
    run_replicated_with_faults(base, n, policy, requests, mem_fraction_each, None)
}

/// [`run_replicated`] with an optional fleet-wide fault plan.
///
/// The plan's events are dealt round-robin across replicas
/// ([`FaultPlan::split`]), each replica injects its share into its own
/// engine, and the front-end router becomes health-aware: a request
/// whose arrival falls inside a replica's crash window
/// ([`FaultPlan::crash_windows`]) is re-routed to a healthy replica
/// (counted in `faults.reroutes`). Everything stays deterministic —
/// the same plan + seed reproduces the same report bit for bit — and
/// `plan = None` is byte-identical to the fault-free path.
pub fn run_replicated_with_faults(
    base: &OfflineConfig,
    n: usize,
    policy: SharePolicy,
    requests: &[Request],
    mem_fraction_each: f64,
    plan: Option<&FaultPlan>,
) -> Result<ReplicatedReport> {
    assert!(n >= 1);
    let mut router = Router::new(RoutePolicy::RoundRobin, n);
    let plans = plan.map(|p| p.split(n));
    let mut reroutes = 0u64;
    let parts = match &plans {
        None => router.partition(requests),
        Some(plans) => {
            // Health-aware partition: walk arrivals in submission order,
            // tracking which replicas sit inside a crash window at each
            // request's arrival instant.
            let windows: Vec<Vec<(f64, f64)>> =
                plans.iter().map(|p| p.crash_windows()).collect();
            let mut out = vec![Vec::new(); n];
            for r in requests {
                for (i, w) in windows.iter().enumerate() {
                    let dead = w.iter().any(|&(s, e)| r.arrival >= s && r.arrival < e);
                    if dead {
                        router.mark_down(i);
                    } else {
                        router.mark_up(i);
                    }
                }
                let (i, rerouted) = router.route_healthy(r);
                if rerouted {
                    reroutes += 1;
                }
                out[i].push(r.clone());
            }
            out
        }
    };

    // Run each replica solo (virtual time) to obtain its trace.
    let mut traces: Vec<Vec<Segment>> = Vec::with_capacity(n);
    let mut solo_reports = Vec::with_capacity(n);
    for (i, part) in parts.iter().enumerate() {
        let mut cfg = base.clone();
        cfg.mem_fraction = mem_fraction_each;
        if let Some(plans) = &plans {
            cfg.faults = Some(plans[i].clone());
        }
        let mut engine = cfg.build_engine();
        engine.submit(part);
        let report = engine.run_to_completion()?;
        let mut trace = report.segments.clone();
        // Stagger replica starts by a fraction of one step so bursts
        // interleave with the others' CPU gaps (the engines would
        // naturally dephase; a synchronized start is the worst case).
        if i > 0 && !trace.is_empty() {
            let first_step = trace
                .iter()
                .take(2)
                .map(|s| s.duration())
                .sum::<f64>();
            traces.push(
                std::iter::once(Segment::Cpu {
                    duration: first_step * i as f64 / n as f64,
                })
                .chain(trace.drain(..))
                .collect(),
            );
        } else {
            traces.push(trace);
        }
        solo_reports.push(report);
    }

    let shared = run_shared(&traces, policy);

    // Contention stretch per replica: shared finish time / solo makespan.
    let stretch: Vec<f64> = solo_reports
        .iter()
        .zip(&shared.finish_times)
        .map(|(r, &f)| {
            if r.metrics.makespan > 0.0 {
                f / r.metrics.makespan
            } else {
                1.0
            }
        })
        .collect();

    let total_tokens: usize = solo_reports
        .iter()
        .map(|r| r.metrics.total_input_tokens + r.metrics.total_output_tokens)
        .sum();
    let mean_itl = solo_reports
        .iter()
        .zip(&stretch)
        .map(|(r, s)| r.metrics.mean_itl * s)
        .sum::<f64>()
        / n as f64;
    let mean_e2e = solo_reports
        .iter()
        .zip(&stretch)
        .map(|(r, s)| r.metrics.mean_e2e * s)
        .sum::<f64>()
        / n as f64;
    let kv_usage = solo_reports
        .iter()
        .map(|r| r.peak_kv_usage)
        .fold(0.0, f64::max);
    let mut faults = FaultStats::default();
    for r in &solo_reports {
        faults.merge(&r.faults);
    }
    faults.reroutes += reroutes;

    Ok(ReplicatedReport {
        replicas: n,
        policy,
        throughput_tps: total_tokens as f64 / shared.makespan.max(1e-12),
        mean_itl,
        mean_e2e,
        kv_usage,
        makespan: shared.makespan,
        cpu_time_frac: shared.gpu_idle_frac,
        mean_dram_util: shared.mean_dram_util,
        stretch,
        solo_metrics: solo_reports.into_iter().map(|r| r.metrics).collect(),
        faults,
        shared,
    })
}

/// Result of a multi-GPU cluster run: `engines` tensor-parallel engines
/// of degree `tp` on a `gpus`-GPU budget.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub engines: usize,
    pub tp: usize,
    pub gpus: usize,
    /// TP groups the budget partitions into (`gpus / tp`); engines are
    /// assigned round-robin, so group populations differ by at most 1.
    pub groups: usize,
    /// Memory fraction granted to the most crowded group's engines
    /// (1 / max engines-per-group).
    pub mem_fraction_each: f64,
    /// Total (input+output) tokens/s over the cluster makespan.
    pub throughput_tps: f64,
    /// Slowest group's shared makespan (seconds).
    pub makespan: f64,
    /// Mean ITL across engines, contention-stretched (seconds).
    pub mean_itl: f64,
    /// Group-span-weighted mean aggregate DRAM demand.
    pub mean_dram_util: f64,
    /// Group-span-weighted GPU-idle share.
    pub cpu_time_frac: f64,
    /// Per-engine contention stretch (shared finish / solo makespan).
    pub stretch: Vec<f64>,
    /// Per-engine solo run metrics (virtual time, pre-contention).
    pub solo_metrics: Vec<RunMetrics>,
    /// Availability accounting merged across engines (all-zero on a
    /// fault-free run).
    pub faults: FaultStats,
}

impl ClusterReport {
    /// Per-request mean ITLs across all engines, stretched by each
    /// engine's contention factor (mirrors
    /// [`ReplicatedReport::stretched_itls`]).
    pub fn stretched_itls(&self) -> Vec<f64> {
        let mut out = Vec::new();
        for (m, &s) in self.solo_metrics.iter().zip(&self.stretch) {
            out.extend(m.latencies.iter().filter_map(|l| l.itl.map(|i| i * s)));
        }
        out
    }

    /// Completed requests across all engines.
    pub fn completed(&self) -> usize {
        self.solo_metrics.iter().map(|m| m.completed).sum()
    }
}

/// Run `engines` tensor-parallel engines of degree `tp` over a budget
/// of `gpus` GPUs.
///
/// The budget splits into `gpus / tp` TP groups. Unsharded (tp = 1)
/// engines land on groups round-robin; engines sharing a group split
/// its memory evenly and contend for its DRAM under `policy` (the
/// single-GPU MPS model, applied per group) — the paper's §VI-B
/// co-location. Sharded (tp >= 2) engines are never co-located:
/// stacking several multi-rank engines on one GPU set is not a
/// supported deployment (vLLM requires `instances × tp <= #GPUs`), and
/// the DRAM-only contention model would flatter it by overlapping
/// their collectives for free. Requests are routed round-robin across
/// engines — the same distribution [`run_replicated`] uses, so
/// `(engines = n, tp = 1, gpus = 1)` reproduces its partitioning.
pub fn run_cluster(
    base: &OfflineConfig,
    engines: usize,
    tp: usize,
    gpus: usize,
    policy: SharePolicy,
    requests: &[Request],
) -> Result<ClusterReport> {
    run_cluster_with_faults(base, engines, tp, gpus, policy, requests, None)
}

/// [`run_cluster`] with an optional fleet-wide fault plan, dealt
/// round-robin across engines like [`run_replicated_with_faults`].
///
/// The front end is health-aware on both paths: a request whose
/// arrival falls inside an engine's crash window is re-routed to a
/// healthy engine (counted in `faults.reroutes`), so the cluster and
/// single-GPU replication paths agree on how faults shape the
/// partition. The engine→group mapping stays the fixed `e % groups`
/// round-robin regardless of health — groups are hardware, not
/// routing state. `plan = None` keeps the plain round-robin deal,
/// byte-identical to the fault-free path.
pub fn run_cluster_with_faults(
    base: &OfflineConfig,
    engines: usize,
    tp: usize,
    gpus: usize,
    policy: SharePolicy,
    requests: &[Request],
    plan: Option<&FaultPlan>,
) -> Result<ClusterReport> {
    ensure!(engines >= 1, "need at least one engine");
    ensure!(tp >= 1, "tensor-parallel degree must be >= 1");
    let groups_avail = gpus.max(1) / tp;
    ensure!(
        groups_avail >= 1,
        "a tp={tp} engine does not fit a {gpus}-GPU budget"
    );
    ensure!(
        tp == 1 || engines <= groups_avail,
        "co-locating tensor-parallel engines is unsupported: {engines} tp={tp} engines \
         need {} GPUs, budget is {gpus}",
        engines * tp
    );
    let groups = groups_avail.min(engines);
    // Round-robin engine -> group; group g hosts engines g, g+groups, ...
    let group_of = |e: usize| e % groups;
    let group_size = |g: usize| (engines - g + groups - 1) / groups;

    let mut router = Router::new(RoutePolicy::RoundRobin, engines);
    let plans = plan.map(|p| p.split(engines));
    let mut reroutes = 0u64;
    let parts = match &plans {
        None => router.partition(requests),
        Some(plans) => {
            // Health-aware partition, same walk as
            // run_replicated_with_faults: track which engines sit
            // inside a crash window at each request's arrival instant.
            let windows: Vec<Vec<(f64, f64)>> =
                plans.iter().map(|p| p.crash_windows()).collect();
            let mut out = vec![Vec::new(); engines];
            for r in requests {
                for (i, w) in windows.iter().enumerate() {
                    let dead = w.iter().any(|&(s, e)| r.arrival >= s && r.arrival < e);
                    if dead {
                        router.mark_down(i);
                    } else {
                        router.mark_up(i);
                    }
                }
                let (i, rerouted) = router.route_healthy(r);
                if rerouted {
                    reroutes += 1;
                }
                out[i].push(r.clone());
            }
            out
        }
    };

    // Solo traces, each engine right-sized to its group's split.
    let mut traces: Vec<Vec<Segment>> = Vec::with_capacity(engines);
    let mut solo_reports = Vec::with_capacity(engines);
    for (e, part) in parts.iter().enumerate() {
        let g = group_of(e);
        let mut cfg = base.clone();
        cfg.tp = tp;
        cfg.mem_fraction = base.mem_fraction / group_size(g) as f64;
        if let Some(plans) = &plans {
            cfg.faults = Some(plans[e].clone());
        }
        let mut engine = cfg.build_engine();
        engine.submit(part);
        let report = engine.run_to_completion()?;
        let mut trace = report.segments.clone();
        // Stagger co-located engines by a fraction of one step so their
        // bursts interleave (same policy as run_replicated).
        let idx_in_group = e / groups;
        let n_in_group = group_size(g);
        if idx_in_group > 0 && !trace.is_empty() {
            let first_step = trace.iter().take(2).map(|s| s.duration()).sum::<f64>();
            traces.push(
                std::iter::once(Segment::Cpu {
                    duration: first_step * idx_in_group as f64 / n_in_group as f64,
                })
                .chain(trace.drain(..))
                .collect(),
            );
        } else {
            traces.push(trace);
        }
        solo_reports.push(report);
    }

    // Co-schedule each group's engines on its GPUs; groups are disjoint
    // hardware, so the cluster makespan is the slowest group's.
    let mut finish = vec![0.0f64; engines];
    let mut makespan = 0.0f64;
    let mut dram_weighted = 0.0f64;
    let mut idle_weighted = 0.0f64;
    let mut span_sum = 0.0f64;
    for g in 0..groups {
        let members: Vec<usize> = (g..engines).step_by(groups).collect();
        let group_traces: Vec<Vec<Segment>> =
            members.iter().map(|&e| traces[e].clone()).collect();
        let shared = run_shared(&group_traces, policy);
        for (slot, &e) in members.iter().enumerate() {
            finish[e] = shared.finish_times[slot];
        }
        makespan = makespan.max(shared.makespan);
        dram_weighted += shared.mean_dram_util * shared.makespan;
        idle_weighted += shared.gpu_idle_frac * shared.makespan;
        span_sum += shared.makespan;
    }

    let stretch: Vec<f64> = solo_reports
        .iter()
        .zip(&finish)
        .map(|(r, &f)| {
            if r.metrics.makespan > 0.0 {
                f / r.metrics.makespan
            } else {
                1.0
            }
        })
        .collect();
    let total_tokens: usize = solo_reports
        .iter()
        .map(|r| r.metrics.total_input_tokens + r.metrics.total_output_tokens)
        .sum();
    let mean_itl = solo_reports
        .iter()
        .zip(&stretch)
        .map(|(r, s)| r.metrics.mean_itl * s)
        .sum::<f64>()
        / engines as f64;
    let max_group = (0..groups).map(group_size).max().unwrap_or(1);
    let mut faults = FaultStats::default();
    for r in &solo_reports {
        faults.merge(&r.faults);
    }
    faults.reroutes += reroutes;

    Ok(ClusterReport {
        engines,
        tp,
        gpus: gpus.max(1),
        groups,
        mem_fraction_each: base.mem_fraction / max_group as f64,
        throughput_tps: total_tokens as f64 / makespan.max(1e-12),
        makespan,
        mean_itl,
        mean_dram_util: if span_sum > 0.0 {
            dram_weighted / span_sum
        } else {
            0.0
        },
        cpu_time_frac: if span_sum > 0.0 {
            idle_weighted / span_sum
        } else {
            0.0
        },
        stretch,
        solo_metrics: solo_reports.into_iter().map(|r| r.metrics).collect(),
        faults,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::spec::ModelSpec;
    use crate::workload::{generate, WorkloadConfig};

    fn opt13_requests(n: usize) -> Vec<Request> {
        generate(&WorkloadConfig::offline(n, 161, 64))
    }

    fn base(b: usize) -> OfflineConfig {
        OfflineConfig::new(ModelSpec::opt_1_3b(), b)
    }

    #[test]
    fn single_replica_matches_solo_run() {
        let reqs = opt13_requests(64);
        let rep = run_replicated(&base(64), 1, SharePolicy::Mps, &reqs, 1.0).unwrap();
        let mut engine = base(64).build_engine();
        engine.submit(&reqs);
        let solo = engine.run_to_completion().unwrap();
        assert!((rep.makespan / solo.metrics.makespan - 1.0).abs() < 1e-6);
        assert!((rep.stretch[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn two_replicas_beat_one_at_bopt_scale() {
        // The paper's §VI-B effect: at B_opt-ish batch, two replicas on
        // freed memory outperform one (CPU gaps + non-saturated phases
        // overlap).
        let reqs = opt13_requests(192);
        let one = run_replicated(&base(96), 1, SharePolicy::Mps, &reqs, 0.4).unwrap();
        let two = run_replicated(&base(96), 2, SharePolicy::Mps, &reqs, 0.4).unwrap();
        assert!(
            two.throughput_tps > 1.1 * one.throughput_tps,
            "1 rep {} vs 2 reps {}",
            one.throughput_tps,
            two.throughput_tps
        );
        // CPU-visible idle shrinks (Table IV: -78%).
        assert!(two.cpu_time_frac < one.cpu_time_frac);
        // DRAM utilization rises (Table IV: 47% -> 67%).
        assert!(two.mean_dram_util > one.mean_dram_util);
        // Per-step contention raises ITL somewhat.
        assert!(two.mean_itl >= one.mean_itl);
    }

    #[test]
    fn mps_beats_fcfs() {
        let reqs = opt13_requests(128);
        let fcfs = run_replicated(&base(64), 2, SharePolicy::Fcfs, &reqs, 0.3).unwrap();
        let mps = run_replicated(&base(64), 2, SharePolicy::Mps, &reqs, 0.3).unwrap();
        assert!(
            mps.throughput_tps >= fcfs.throughput_tps,
            "mps {} vs fcfs {}",
            mps.throughput_tps,
            fcfs.throughput_tps
        );
    }

    #[test]
    fn solo_metrics_expose_per_request_latencies_under_contention() {
        let reqs = opt13_requests(64);
        let rep = run_replicated(&base(32), 2, SharePolicy::Mps, &reqs, 0.4).unwrap();
        assert_eq!(rep.solo_metrics.len(), 2);
        assert_eq!(rep.completed(), 64);
        // Every request decodes 64 tokens, so each contributes an ITL.
        let stretched = rep.stretched_itls();
        assert_eq!(stretched.len(), 64);
        let solo: f64 = rep
            .solo_metrics
            .iter()
            .flat_map(|m| m.latencies.iter().filter_map(|l| l.itl))
            .sum();
        // Contention can only stretch latencies.
        assert!(stretched.iter().sum::<f64>() >= solo * 0.999);
    }

    #[test]
    fn single_group_cluster_matches_run_replicated() {
        // (2 engines, tp=1, 1 GPU) is exactly run_replicated's setup:
        // same partitioning, same stagger, same shared schedule.
        let reqs = opt13_requests(64);
        let rep = run_replicated(&base(32), 2, SharePolicy::Mps, &reqs, 0.5).unwrap();
        let clu = run_cluster(&base(32), 2, 1, 1, SharePolicy::Mps, &reqs).unwrap();
        assert_eq!(clu.groups, 1);
        assert_eq!(clu.makespan, rep.makespan);
        assert_eq!(clu.completed(), rep.completed());
        assert_eq!(clu.stretched_itls(), rep.stretched_itls());
    }

    #[test]
    fn dedicated_gpus_run_contention_free() {
        let reqs = opt13_requests(64);
        let clu = run_cluster(&base(32), 2, 1, 2, SharePolicy::Mps, &reqs).unwrap();
        assert_eq!(clu.groups, 2);
        assert_eq!(clu.mem_fraction_each, 1.0);
        // Each engine owns its GPU: no stretch beyond numerical noise.
        for &s in &clu.stretch {
            assert!((s - 1.0).abs() < 1e-9, "{s}");
        }
        // And the two halves overlap, so the cluster finishes in about
        // half the single-engine time.
        let solo = run_cluster(&base(32), 1, 1, 1, SharePolicy::Mps, &reqs).unwrap();
        assert!(clu.makespan < 0.75 * solo.makespan);
        assert!(clu.throughput_tps > 1.5 * solo.throughput_tps);
    }

    #[test]
    fn replication_beats_tp_sharding_for_a_small_model_on_two_gpus() {
        // The derived §VI-B claim: on the same 2-GPU budget, two tp=1
        // replicas outperform one tp=2 sharded engine for OPT-1.3B —
        // replication parallelizes the host loop and both HBMs, while
        // sharding halves only the GPU burst and pays collectives.
        // 192 requests = one full B=96 wave per replica.
        let reqs = opt13_requests(192);
        let rep = run_cluster(&base(96), 2, 1, 2, SharePolicy::Mps, &reqs).unwrap();
        let shard = run_cluster(&base(96), 1, 2, 2, SharePolicy::Mps, &reqs).unwrap();
        assert_eq!(rep.completed(), shard.completed());
        assert!(
            rep.throughput_tps > 1.1 * shard.throughput_tps,
            "replication {} vs sharding {}",
            rep.throughput_tps,
            shard.throughput_tps
        );
    }

    #[test]
    fn cluster_rejects_oversized_tp() {
        let reqs = opt13_requests(8);
        assert!(run_cluster(&base(8), 1, 4, 2, SharePolicy::Mps, &reqs).is_err());
    }

    #[test]
    fn stretch_is_at_least_one() {
        let reqs = opt13_requests(96);
        let rep = run_replicated(&base(48), 3, SharePolicy::Mps, &reqs, 0.25).unwrap();
        for &s in &rep.stretch {
            assert!(s >= 0.99, "{s}");
        }
        assert_eq!(rep.stretch.len(), 3);
    }
}
