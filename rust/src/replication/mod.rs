//! Model replication on one GPU (paper §VI-B, Fig 13, Table IV).
//!
//! With BCA freeing most of the KV allocation, multiple engine replicas
//! fit on the same device. Each replica gets an equal share of the
//! usable memory, requests are routed round-robin (the paper
//! distributes them evenly), and the replicas' CPU/GPU traces are
//! co-scheduled by the MPS processor-sharing executor (or FCFS
//! time-sharing as the baseline).
//!
//! Methodology note (documented in DESIGN.md §2): each replica's engine
//! runs against the simulator in its own virtual time producing an
//! alternating CPU-gap / GPU-burst trace; `gpusim::mps::run_shared`
//! then co-schedules those traces on one device. Per-replica slowdown
//! from contention is applied to the latency metrics; throughput comes
//! from total tokens over the shared makespan.

use anyhow::Result;

use crate::coordinator::offline::OfflineConfig;
use crate::coordinator::router::{RoutePolicy, Router};
use crate::gpusim::mps::{run_shared, Segment, SharePolicy, SharedRun};
use crate::workload::Request;

/// Result of a replicated serving run.
#[derive(Debug, Clone)]
pub struct ReplicatedReport {
    pub replicas: usize,
    pub policy: SharePolicy,
    /// Total (input+output) tokens per second across replicas.
    pub throughput_tps: f64,
    /// Mean ITL across replicas, contention-stretched (seconds).
    pub mean_itl: f64,
    /// Mean E2E across replicas, contention-stretched (seconds).
    pub mean_e2e: f64,
    /// Peak KV usage per replica (fraction of the replica's pool).
    pub kv_usage: f64,
    /// Shared-run makespan (seconds).
    pub makespan: f64,
    /// Fraction of the makespan with NO GPU kernel running — the
    /// "CPU time" column of Table IV.
    pub cpu_time_frac: f64,
    /// Time-averaged aggregate DRAM demand (Table IV "DRAM read").
    pub mean_dram_util: f64,
    /// Per-replica contention stretch (shared finish / solo finish).
    pub stretch: Vec<f64>,
    /// Per-replica solo run metrics (virtual time, pre-contention);
    /// combined with `stretch` they give per-request latencies under
    /// contention — the SLO planner's percentile surface.
    pub solo_metrics: Vec<crate::metrics::RunMetrics>,
    /// The shared schedule, for Fig-13-style timelines.
    pub shared: SharedRun,
}

impl ReplicatedReport {
    /// Per-request mean ITLs across all replicas, each stretched by its
    /// replica's contention factor (single-token requests excluded).
    pub fn stretched_itls(&self) -> Vec<f64> {
        let mut out = Vec::new();
        for (m, &s) in self.solo_metrics.iter().zip(&self.stretch) {
            out.extend(m.latencies.iter().filter_map(|l| l.itl.map(|i| i * s)));
        }
        out
    }

    /// Completed requests across replicas.
    pub fn completed(&self) -> usize {
        self.solo_metrics.iter().map(|m| m.completed).sum()
    }
}

/// Run `base` replicated `n` ways under `policy` over `requests`.
///
/// `mem_fraction_each` is each replica's share of the usable memory
/// (BCA's `engine_mem_fraction`, or 1/n for an even split).
pub fn run_replicated(
    base: &OfflineConfig,
    n: usize,
    policy: SharePolicy,
    requests: &[Request],
    mem_fraction_each: f64,
) -> Result<ReplicatedReport> {
    assert!(n >= 1);
    let mut router = Router::new(RoutePolicy::RoundRobin, n);
    let parts = router.partition(requests);

    // Run each replica solo (virtual time) to obtain its trace.
    let mut traces: Vec<Vec<Segment>> = Vec::with_capacity(n);
    let mut solo_reports = Vec::with_capacity(n);
    for (i, part) in parts.iter().enumerate() {
        let mut cfg = base.clone();
        cfg.mem_fraction = mem_fraction_each;
        let mut engine = cfg.build_engine();
        engine.submit(part);
        let report = engine.run_to_completion()?;
        let mut trace = report.segments.clone();
        // Stagger replica starts by a fraction of one step so bursts
        // interleave with the others' CPU gaps (the engines would
        // naturally dephase; a synchronized start is the worst case).
        if i > 0 && !trace.is_empty() {
            let first_step = trace
                .iter()
                .take(2)
                .map(|s| s.duration())
                .sum::<f64>();
            traces.push(
                std::iter::once(Segment::Cpu {
                    duration: first_step * i as f64 / n as f64,
                })
                .chain(trace.drain(..))
                .collect(),
            );
        } else {
            traces.push(trace);
        }
        solo_reports.push(report);
    }

    let shared = run_shared(&traces, policy);

    // Contention stretch per replica: shared finish time / solo makespan.
    let stretch: Vec<f64> = solo_reports
        .iter()
        .zip(&shared.finish_times)
        .map(|(r, &f)| {
            if r.metrics.makespan > 0.0 {
                f / r.metrics.makespan
            } else {
                1.0
            }
        })
        .collect();

    let total_tokens: usize = solo_reports
        .iter()
        .map(|r| r.metrics.total_input_tokens + r.metrics.total_output_tokens)
        .sum();
    let mean_itl = solo_reports
        .iter()
        .zip(&stretch)
        .map(|(r, s)| r.metrics.mean_itl * s)
        .sum::<f64>()
        / n as f64;
    let mean_e2e = solo_reports
        .iter()
        .zip(&stretch)
        .map(|(r, s)| r.metrics.mean_e2e * s)
        .sum::<f64>()
        / n as f64;
    let kv_usage = solo_reports
        .iter()
        .map(|r| r.peak_kv_usage)
        .fold(0.0, f64::max);

    Ok(ReplicatedReport {
        replicas: n,
        policy,
        throughput_tps: total_tokens as f64 / shared.makespan.max(1e-12),
        mean_itl,
        mean_e2e,
        kv_usage,
        makespan: shared.makespan,
        cpu_time_frac: shared.gpu_idle_frac,
        mean_dram_util: shared.mean_dram_util,
        stretch,
        solo_metrics: solo_reports.into_iter().map(|r| r.metrics).collect(),
        shared,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::spec::ModelSpec;
    use crate::workload::{generate, WorkloadConfig};

    fn opt13_requests(n: usize) -> Vec<Request> {
        generate(&WorkloadConfig::offline(n, 161, 64))
    }

    fn base(b: usize) -> OfflineConfig {
        OfflineConfig::new(ModelSpec::opt_1_3b(), b)
    }

    #[test]
    fn single_replica_matches_solo_run() {
        let reqs = opt13_requests(64);
        let rep = run_replicated(&base(64), 1, SharePolicy::Mps, &reqs, 1.0).unwrap();
        let mut engine = base(64).build_engine();
        engine.submit(&reqs);
        let solo = engine.run_to_completion().unwrap();
        assert!((rep.makespan / solo.metrics.makespan - 1.0).abs() < 1e-6);
        assert!((rep.stretch[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn two_replicas_beat_one_at_bopt_scale() {
        // The paper's §VI-B effect: at B_opt-ish batch, two replicas on
        // freed memory outperform one (CPU gaps + non-saturated phases
        // overlap).
        let reqs = opt13_requests(192);
        let one = run_replicated(&base(96), 1, SharePolicy::Mps, &reqs, 0.4).unwrap();
        let two = run_replicated(&base(96), 2, SharePolicy::Mps, &reqs, 0.4).unwrap();
        assert!(
            two.throughput_tps > 1.1 * one.throughput_tps,
            "1 rep {} vs 2 reps {}",
            one.throughput_tps,
            two.throughput_tps
        );
        // CPU-visible idle shrinks (Table IV: -78%).
        assert!(two.cpu_time_frac < one.cpu_time_frac);
        // DRAM utilization rises (Table IV: 47% -> 67%).
        assert!(two.mean_dram_util > one.mean_dram_util);
        // Per-step contention raises ITL somewhat.
        assert!(two.mean_itl >= one.mean_itl);
    }

    #[test]
    fn mps_beats_fcfs() {
        let reqs = opt13_requests(128);
        let fcfs = run_replicated(&base(64), 2, SharePolicy::Fcfs, &reqs, 0.3).unwrap();
        let mps = run_replicated(&base(64), 2, SharePolicy::Mps, &reqs, 0.3).unwrap();
        assert!(
            mps.throughput_tps >= fcfs.throughput_tps,
            "mps {} vs fcfs {}",
            mps.throughput_tps,
            fcfs.throughput_tps
        );
    }

    #[test]
    fn solo_metrics_expose_per_request_latencies_under_contention() {
        let reqs = opt13_requests(64);
        let rep = run_replicated(&base(32), 2, SharePolicy::Mps, &reqs, 0.4).unwrap();
        assert_eq!(rep.solo_metrics.len(), 2);
        assert_eq!(rep.completed(), 64);
        // Every request decodes 64 tokens, so each contributes an ITL.
        let stretched = rep.stretched_itls();
        assert_eq!(stretched.len(), 64);
        let solo: f64 = rep
            .solo_metrics
            .iter()
            .flat_map(|m| m.latencies.iter().filter_map(|l| l.itl))
            .sum();
        // Contention can only stretch latencies.
        assert!(stretched.iter().sum::<f64>() >= solo * 0.999);
    }

    #[test]
    fn stretch_is_at_least_one() {
        let reqs = opt13_requests(96);
        let rep = run_replicated(&base(48), 3, SharePolicy::Mps, &reqs, 0.25).unwrap();
        for &s in &rep.stretch {
            assert!(s >= 0.99, "{s}");
        }
        assert_eq!(rep.stretch.len(), 3);
    }
}
