"""L1: Pallas kernels for the paper's compute hot spots.

- ``paged_attention`` — decode-step attention over a paged KV cache (the
  DRAM-bound kernel the paper identifies as the large-batch bottleneck).
- ``flash_attention`` — tiled causal attention for the prefill phase.
- ``matmul`` — blocked GEMM for projections / FFN.
- ``ref`` — pure-jnp oracles for all of the above.

Every kernel runs ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); each module also exposes ``io_bytes``/``flops`` analytic
cost functions mirrored by ``rust/src/gpusim/kernels.rs``.
"""

from . import flash_attention, matmul, paged_attention, ref  # noqa: F401

__all__ = ["flash_attention", "matmul", "paged_attention", "ref"]
