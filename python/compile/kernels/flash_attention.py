"""L1 Pallas kernel: tiled causal attention for the prefill phase.

FlashAttention-style schedule expressed for TPU: the grid iterates
(batch, head, q-block); each program holds one Q tile in VMEM and streams
K/V tiles HBM->VMEM, maintaining the online-softmax running state in
VMEM scratch. This is the direct analogue of the CUDA threadblock tiling
the paper profiles — ``BlockSpec`` plays the role of the threadblock
HBM<->shared-memory schedule (DESIGN.md §Hardware-Adaptation).

The causal structure is exploited at block granularity: K blocks entirely
above the diagonal are skipped (the fori_loop upper bound is the last
block visible to this Q tile), which is the same work-skipping
FlashAttention performs.

``interpret=True`` always (CPU PJRT cannot run Mosaic custom-calls).
Correctness: python/tests/test_flash_attention.py sweeps shapes/dtypes
against ``ref.ref_attention``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # [1, H, block_q, D]
    k_ref,  # [1, H, T, D]
    v_ref,  # [1, H, T, D]
    o_ref,  # [1, H, block_q, D]
    *,
    block_q: int,
    block_k: int,
    seq_len: int,
    kv_len: int,
    scale: float,
    causal: bool,
):
    h, d = q_ref.shape[1], q_ref.shape[-1]
    qi = pl.program_id(1)
    # All heads in one program (amortizes interpret-mode grid overhead,
    # EXPERIMENTS.md §Perf L1); the per-head IO schedule is unchanged.
    q = q_ref[0].astype(jnp.float32) * scale  # [H, bq, D]

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)  # [bq]
    offset = kv_len - seq_len  # causal offset for cached prefixes

    def body(j, carry):
        m_prev, l_prev, acc_prev = carry  # [H,bq], [H,bq], [H,bq,D]
        k = pl.load(k_ref, (0, slice(None), pl.ds(j * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, slice(None), pl.ds(j * block_k, block_k), slice(None)))
        s = jnp.einsum("hqd,hkd->hqk", q, k.astype(jnp.float32))  # [H,bq,bk]
        k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)  # [bk]
        mask = k_pos[None, :] < kv_len
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None] + offset)
        s = jnp.where(mask[None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=2))  # [H,bq]
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, :, None])  # [H,bq,bk]
        l_new = l_prev * alpha + p.sum(axis=2)
        acc_new = acc_prev * alpha[:, :, None] + jnp.einsum(
            "hqk,hkd->hqd", p, v.astype(jnp.float32)
        )
        return m_new, l_new, acc_new

    n_k_blocks = (kv_len + block_k - 1) // block_k
    if causal:
        # Last K block this Q tile can see: query row (qi+1)*bq - 1 attends
        # up to key index row + offset.
        last_visible = (qi + 1) * block_q - 1 + offset
        n_visible = jnp.minimum((last_visible + block_k) // block_k, n_k_blocks)
    else:
        n_visible = n_k_blocks

    m0 = jnp.full((h, block_q), NEG_INF, jnp.float32)
    l0 = jnp.zeros((h, block_q), jnp.float32)
    acc0 = jnp.zeros((h, block_q, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_visible, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, :, None]).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,  # [B, S, H, D]
    k: jnp.ndarray,  # [B, T, H, D]
    v: jnp.ndarray,  # [B, T, H, D]
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 32,
    block_k: int = 32,
) -> jnp.ndarray:
    """Tiled multi-head attention. Returns [B, S, H, D]."""
    b, s, h, d = q.shape
    t = k.shape[1]
    if scale is None:
        scale = 1.0 / (d**0.5)

    block_q = min(block_q, s)
    block_k = min(block_k, t)
    # Pad sequence dims up to multiples of the tile sizes.
    s_pad = (s + block_q - 1) // block_q * block_q
    t_pad = (t + block_k - 1) // block_k * block_k
    qt = jnp.moveaxis(q, 2, 1)  # [B, H, S, D]
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if s_pad != s:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    if t_pad != t:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))

    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q,
        block_k=block_k,
        seq_len=s,
        kv_len=t,
        scale=scale,
        causal=causal,
    )
    grid = (b, s_pad // block_q)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, h, block_q, d), lambda i, k_: (i, 0, k_, 0)),
            pl.BlockSpec((1, h, t_pad, d), lambda i, k_: (i, 0, 0, 0)),
            pl.BlockSpec((1, h, t_pad, d), lambda i, k_: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, block_q, d), lambda i, k_: (i, 0, k_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s_pad, d), q.dtype),
        interpret=True,
    )(qt, kt, vt)
    return jnp.moveaxis(out[:, :, :s, :], 1, 2)  # [B, S, H, D]


# ----------------------------------------------------------------------
# Analytic cost model (mirrored by rust/src/gpusim/kernels.rs)
# ----------------------------------------------------------------------


def io_bytes(
    batch: int,
    seq: int,
    kv: int,
    heads: int,
    head_dim: int,
    *,
    block_q: int = 32,
    dtype_bytes: int = 2,
) -> int:
    """HBM traffic of the tiled kernel: Q/O once, K/V once per Q tile."""
    n_q_tiles = (seq + block_q - 1) // block_q
    qo = 2 * batch * heads * seq * head_dim * dtype_bytes
    kv_traffic = 2 * batch * heads * kv * head_dim * dtype_bytes * n_q_tiles
    return qo + kv_traffic


def flops(batch: int, seq: int, kv: int, heads: int, head_dim: int, *, causal: bool = True) -> int:
    """QK^T + PV FLOPs; causal halves the score work."""
    pairs = seq * kv
    if causal:
        pairs = pairs // 2 + seq // 2
    return 4 * batch * heads * pairs * head_dim
