"""L1 Pallas kernel: paged decode attention (the paper's hot spot).

One decode step computes, for every sequence in the batch, attention of a
single query token against that sequence's KV history stored in a *paged*
cache (vLLM PagedAttention layout): physical KV blocks of ``block_size``
token slots, indirected through a per-sequence block table. The paper
(§V-C) shows this kernel is the large-batch bottleneck: its arithmetic
intensity is ~1 FLOP/byte independent of batch size, so it pins DRAM read
bandwidth while the MXU/SMs idle.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the CUDA kernels the
paper profiles (xFormers / FlashAttention) stage KV tiles through shared
memory per threadblock; here each grid program (one per (sequence, head))
streams the sequence's KV blocks HBM->VMEM and keeps the *online softmax*
running state (m, l, acc) in VMEM scratch, which is exactly the
FlashAttention-style IO schedule expressed with Pallas. The KV caches are
handed to the kernel unblocked (per-head slab) because the block table
indirection is data-dependent; ``pl.load`` with dynamic slices expresses
the HBM->VMEM gather. ``interpret=True`` always: the CPU PJRT plugin
cannot run Mosaic custom-calls (see /opt/xla-example/README.md).

Cost model hooks: ``io_bytes`` / ``flops`` report the kernel's analytic
HBM traffic and FLOP count; `rust/src/gpusim/kernels.rs` mirrors these
formulas (they are asserted equal in python/tests/test_costmodel.py via
golden values).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _paged_attn_kernel(
    # inputs
    q_ref,  # [1, H, D]            queries for seq b (all heads)
    kc_ref,  # [H, num_slots, D]   full K cache
    vc_ref,  # [H, num_slots, D]   full V cache
    bt_ref,  # [1, max_blocks]     block table row for seq b
    len_ref,  # [1]                context length for seq b
    # outputs
    o_ref,  # [1, H, D]
    *,
    block_size: int,
    max_blocks: int,
    scale: float,
):
    h, d = q_ref.shape[-2], q_ref.shape[-1]
    q = q_ref[0].astype(jnp.float32) * scale  # [H, D]
    ctx_len = len_ref[0]

    def body(i, carry):
        m_prev, l_prev, acc_prev = carry  # [H], [H], [H, D]
        phys = bt_ref[0, i]
        start = phys * block_size
        # HBM -> VMEM: one KV block, all heads (grid is one program per
        # sequence; processing heads together amortizes program overhead
        # — §Perf L1, same IO schedule as the per-head variant).
        k = pl.load(kc_ref, (slice(None), pl.ds(start, block_size), slice(None)))
        v = pl.load(vc_ref, (slice(None), pl.ds(start, block_size), slice(None)))
        # [H, bs]
        s = jnp.einsum("hd,htd->ht", q, k.astype(jnp.float32))
        pos = i * block_size + jax.lax.iota(jnp.int32, block_size)
        s = jnp.where(pos[None, :] < ctx_len, s, NEG_INF)
        # Online softmax update (FlashAttention recurrence), per head.
        m_new = jnp.maximum(m_prev, s.max(axis=1))  # [H]
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])  # [H, bs]
        l_new = l_prev * alpha + p.sum(axis=1)
        acc_new = acc_prev * alpha[:, None] + jnp.einsum(
            "ht,htd->hd", p, v.astype(jnp.float32)
        )
        return m_new, l_new, acc_new

    # Only blocks that can contain valid tokens need visiting; the grid is
    # static so we loop over the sequence's used blocks and mask the tail.
    n_used = (ctx_len + block_size - 1) // block_size
    m0 = jnp.full((h,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((h,), jnp.float32)
    acc0 = jnp.zeros((h, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_used, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def paged_decode_attention(
    q: jnp.ndarray,  # [B, H, D]
    k_cache: jnp.ndarray,  # [H, num_slots, D]
    v_cache: jnp.ndarray,  # [H, num_slots, D]
    block_tables: jnp.ndarray,  # [B, max_blocks] int32
    context_lens: jnp.ndarray,  # [B] int32
    *,
    block_size: int,
    scale: float | None = None,
) -> jnp.ndarray:
    """Decode-step paged attention. Returns [B, H, D].

    Grid is (B,): one program per sequence, streaming that sequence's KV
    blocks (all heads together) through VMEM with an online-softmax
    accumulator. Heads-per-program amortizes grid overhead ~Hx in
    interpret mode and matches vLLM's per-sequence work partitioning
    (EXPERIMENTS.md §Perf, L1).
    """
    b, h, d = q.shape
    num_slots = k_cache.shape[1]
    max_blocks = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / (d**0.5)
    assert num_slots % block_size == 0

    kernel = functools.partial(
        _paged_attn_kernel,
        block_size=block_size,
        max_blocks=max_blocks,
        scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda i: (i, 0, 0)),  # q
            pl.BlockSpec((h, num_slots, d), lambda i: (0, 0, 0)),  # k cache
            pl.BlockSpec((h, num_slots, d), lambda i: (0, 0, 0)),  # v cache
            pl.BlockSpec((1, max_blocks), lambda i: (i, 0)),  # block table
            pl.BlockSpec((1,), lambda i: (i,)),  # ctx len
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, k_cache, v_cache, block_tables, context_lens)


# ----------------------------------------------------------------------
# Analytic cost model (mirrored by rust/src/gpusim/kernels.rs)
# ----------------------------------------------------------------------


def io_bytes(
    batch: int, heads: int, head_dim: int, ctx_lens, *, block_size: int, dtype_bytes: int = 2
) -> int:
    """HBM bytes moved by one decode-attention call.

    Per sequence: K+V blocks covering ctx_len (rounded up to block_size),
    all heads, plus Q read and O write. Block tables / lengths are noise.
    """
    total = 0
    for ctx in ctx_lens:
        padded = ((ctx + block_size - 1) // block_size) * block_size
        total += 2 * heads * padded * head_dim * dtype_bytes  # K + V
    total += 2 * batch * heads * head_dim * dtype_bytes  # Q read + O write
    return total


def flops(batch: int, heads: int, head_dim: int, ctx_lens) -> int:
    """FLOPs of one decode-attention call: qK^T and pV, 2 MACs each."""
    total = 0
    for ctx in ctx_lens:
        total += 4 * heads * ctx * head_dim
    return total
