"""L1 Pallas kernel: blocked matmul (the projection / FFN GEMMs).

The paper's Figure 1 contrasts matmul kernels — whose arithmetic
intensity *grows* with batch size because the weight tile is amortized
over more rows — with attention kernels whose AI is constant. This kernel
is the matmul half of that comparison and the GEMM used by the L2 model's
linear layers.

TPU mapping: the grid tiles the output (M/bm, N/bn); each program keeps
an f32 accumulator tile in VMEM and streams A-row / B-column panels
HBM->VMEM, feeding the MXU-shaped ``jnp.dot``. ``interpret=True`` always.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref, *, block_k: int, k_dim: int):
    # a_ref [bm, K], b_ref [K, bn], o_ref [bm, bn]
    bm, _ = a_ref.shape
    _, bn = b_ref.shape

    def body(i, acc):
        a = pl.load(a_ref, (slice(None), pl.ds(i * block_k, block_k)))
        b = pl.load(b_ref, (pl.ds(i * block_k, block_k), slice(None)))
        return acc + jnp.dot(
            a.astype(jnp.float32), b.astype(jnp.float32), precision="highest"
        )

    n_k = k_dim // block_k
    acc = jax.lax.fori_loop(0, n_k, body, jnp.zeros((bm, bn), jnp.float32))
    o_ref[...] = acc.astype(o_ref.dtype)


def matmul(
    a: jnp.ndarray,  # [M, K]
    b: jnp.ndarray,  # [K, N]
    *,
    block_m: int = 32,
    block_n: int = 32,
    block_k: int = 32,
) -> jnp.ndarray:
    """Blocked matmul with f32 accumulation. Returns [M, N]."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)

    def pad_to(x, axis, mult):
        size = x.shape[axis]
        pad = (size + mult - 1) // mult * mult - size
        if pad == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        return jnp.pad(x, widths)

    ap = pad_to(pad_to(a, 0, block_m), 1, block_k)
    bp = pad_to(pad_to(b, 0, block_k), 1, block_n)
    mp, kp = ap.shape
    _, np_ = bp.shape

    kernel = functools.partial(_matmul_kernel, block_k=block_k, k_dim=kp)
    out = pl.pallas_call(
        kernel,
        grid=(mp // block_m, np_ // block_n),
        in_specs=[
            pl.BlockSpec((block_m, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((kp, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


# ----------------------------------------------------------------------
# Analytic cost model (mirrored by rust/src/gpusim/kernels.rs)
# ----------------------------------------------------------------------


def io_bytes(
    m: int,
    k: int,
    n: int,
    *,
    block_m: int = 32,
    block_n: int = 32,
    dtype_bytes: int = 2,
) -> int:
    """HBM traffic: each A panel read once per N tile, B per M tile, O once.

    For the decode GEMV case (m = batch, n = d_out) this reduces to
    ``weights + batch * (k + n)`` — the weight term dominates at small
    batch, which is why matmul AI grows with batch (paper Fig. 1).
    """
    n_m = (m + block_m - 1) // block_m
    n_n = (n + block_n - 1) // block_n
    a_traffic = m * k * n_n * dtype_bytes
    b_traffic = k * n * n_m * dtype_bytes
    o_traffic = m * n * dtype_bytes
    return a_traffic + b_traffic + o_traffic


def flops(m: int, k: int, n: int) -> int:
    return 2 * m * k * n
