"""Pure-jnp oracles for every Pallas kernel in this package.

These references are the correctness ground truth: pytest (see
``python/tests``) sweeps shapes/dtypes with hypothesis and asserts
``assert_allclose(kernel(...), ref(...))``. They are also imported by
``model.py`` when building the non-paged reference model used by the
end-to-end model tests.

Everything here is deliberately written in the most direct jnp style —
no tiling, no online softmax — so a mismatch always points at the kernel.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30  # large-but-finite; matches the kernels' masking constant


def ref_matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain matmul oracle, accumulating in f32."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32)).astype(a.dtype)


def ref_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jnp.ndarray:
    """Multi-head attention oracle.

    Shapes: q [B, S, H, D], k/v [B, T, H, D] -> out [B, S, H, D].
    ``causal`` masks position j > i + (T - S) (standard causal offset so a
    query block at the end of a longer key sequence sees its prefix).
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    if scale is None:
        scale = 1.0 / (d**0.5)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # [B, H, S, T]
    scores = jnp.einsum("bshd,bthd->bhst", qf, kf) * scale
    if causal:
        offset = t - s
        qi = jnp.arange(s)[:, None]
        kj = jnp.arange(t)[None, :]
        mask = kj <= qi + offset
        scores = jnp.where(mask[None, None, :, :], scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhst,bthd->bshd", probs, vf)
    return out.astype(q.dtype)


def gather_kv(
    cache: jnp.ndarray,
    block_table: jnp.ndarray,
    *,
    block_size: int,
    max_len: int,
) -> jnp.ndarray:
    """Gather one sequence's K or V rows from a paged cache.

    cache [H, num_slots, D] (slots = blocks * block_size), block_table
    [max_blocks] of physical block ids -> [H, max_len, D] where row ``i``
    comes from slot ``block_table[i // bs] * bs + i % bs``.
    """
    positions = jnp.arange(max_len)
    phys = block_table[positions // block_size] * block_size + positions % block_size
    return cache[:, phys, :]


def ref_paged_decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    block_tables: jnp.ndarray,
    context_lens: jnp.ndarray,
    *,
    block_size: int,
    scale: float | None = None,
) -> jnp.ndarray:
    """Decode-step attention oracle over a paged KV cache.

    q [B, H, D]; k_cache/v_cache [H, num_slots, D]; block_tables
    [B, max_blocks]; context_lens [B] -> out [B, H, D].

    Each query attends to its sequence's first ``context_lens[b]`` cached
    positions, gathered through the block table (vLLM PagedAttention
    semantics).
    """
    b, h, d = q.shape
    max_blocks = block_tables.shape[1]
    max_len = max_blocks * block_size
    if scale is None:
        scale = 1.0 / (d**0.5)

    outs = []
    for i in range(b):
        k = gather_kv(k_cache, block_tables[i], block_size=block_size, max_len=max_len)
        v = gather_kv(v_cache, block_tables[i], block_size=block_size, max_len=max_len)
        # [H, max_len]
        scores = (
            jnp.einsum("hd,htd->ht", q[i].astype(jnp.float32), k.astype(jnp.float32))
            * scale
        )
        mask = jnp.arange(max_len)[None, :] < context_lens[i]
        scores = jnp.where(mask, scores, NEG_INF)
        probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
        probs = probs / probs.sum(axis=-1, keepdims=True)
        outs.append(jnp.einsum("ht,htd->hd", probs, v.astype(jnp.float32)))
    return jnp.stack(outs).astype(q.dtype)
