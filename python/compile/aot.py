"""AOT compile path: lower the L2 model to HLO text artifacts.

Run once via ``make artifacts`` (no-op when inputs are unchanged); the
rust runtime (``rust/src/runtime``) is self-contained afterwards.

Emits, under ``artifacts/``:

- ``decode_b{B}.hlo.txt``       — one decode step per batch bucket B
- ``prefill_b{B}_s{S}.hlo.txt`` — prefill per (batch, padded-seq) bucket
- ``weights.bin``               — f32 little-endian tensors, WEIGHT_ORDER
- ``manifest.json``             — model config, weight index, executable
                                  index with the exact input signature the
                                  rust side must honour

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids that the crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
Lowering uses ``return_tuple=True``; the rust side unwraps with
``decompose_tuple``.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

# Bucket ladders. The coordinator pads a running batch up to the nearest
# bucket; anything larger is split across steps by the scheduler.
DECODE_BUCKETS: Sequence[int] = (1, 2, 4, 8)
PREFILL_BUCKETS: Sequence[Tuple[int, int]] = ((1, 64), (2, 64), (4, 64), (8, 64))

PRESETS: Dict[str, M.ModelConfig] = {
    # End-to-end example model (~7.9M params).
    "tiny-opt": M.ModelConfig(
        name="tiny-opt",
        n_layers=4,
        d_model=256,
        n_heads=8,
        vocab_size=8192,
        max_seq=512,
        block_size=16,
        num_blocks=256,
        max_blocks_per_seq=16,
    ),
    # Fast preset for CI / pytest round-trip tests (~0.2M params).
    "micro-opt": M.ModelConfig(
        name="micro-opt",
        n_layers=2,
        d_model=64,
        n_heads=4,
        vocab_size=512,
        max_seq=128,
        block_size=8,
        num_blocks=64,
        max_blocks_per_seq=8,
    ),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape: Tuple[int, ...], dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def _cache_specs(cfg: M.ModelConfig) -> List[jax.ShapeDtypeStruct]:
    shape = (cfg.n_layers, cfg.n_heads, cfg.num_slots, cfg.head_dim)
    return [_spec(shape, jnp.float32), _spec(shape, jnp.float32)]


def _weight_specs(cfg: M.ModelConfig) -> List[jax.ShapeDtypeStruct]:
    shapes = M.weight_shapes(cfg)
    return [_spec(shapes[n], jnp.float32) for n in M.WEIGHT_ORDER]


def lower_decode(cfg: M.ModelConfig, batch: int) -> str:
    """Lower one decode step for a batch bucket to HLO text."""

    def fn(tokens, block_tables, context_lens, slot_mapping, k_cache, v_cache, *weights):
        params = dict(zip(M.WEIGHT_ORDER, weights))
        return M.decode_step(
            params, cfg, tokens, block_tables, context_lens, slot_mapping, k_cache, v_cache
        )

    specs = [
        _spec((batch,), jnp.int32),  # tokens
        _spec((batch, cfg.max_blocks_per_seq), jnp.int32),  # block_tables
        _spec((batch,), jnp.int32),  # context_lens
        _spec((batch,), jnp.int32),  # slot_mapping
        *_cache_specs(cfg),
        *_weight_specs(cfg),
    ]
    # Donate the KV caches: XLA updates them in place instead of copying
    # the whole slab per layer scatter (EXPERIMENTS.md §Perf, L2).
    return to_hlo_text(jax.jit(fn, donate_argnums=(4, 5)).lower(*specs))


def lower_prefill(cfg: M.ModelConfig, batch: int, seq: int) -> str:
    """Lower a prefill bucket to HLO text."""

    def fn(tokens, prompt_lens, slot_mapping, k_cache, v_cache, *weights):
        params = dict(zip(M.WEIGHT_ORDER, weights))
        return M.prefill(params, cfg, tokens, prompt_lens, slot_mapping, k_cache, v_cache)

    specs = [
        _spec((batch, seq), jnp.int32),  # tokens
        _spec((batch,), jnp.int32),  # prompt_lens
        _spec((batch, seq), jnp.int32),  # slot_mapping
        *_cache_specs(cfg),
        *_weight_specs(cfg),
    ]
    return to_hlo_text(jax.jit(fn, donate_argnums=(3, 4)).lower(*specs))


def dump_weights(cfg: M.ModelConfig, out_dir: pathlib.Path, seed: int) -> List[dict]:
    """Write weights.bin; return the manifest tensor index."""
    params = M.init_params(cfg, seed=seed)
    index: List[dict] = []
    offset = 0
    with open(out_dir / "weights.bin", "wb") as f:
        for name in M.WEIGHT_ORDER:
            arr = np.asarray(params[name], dtype=np.float32)
            raw = arr.tobytes(order="C")
            f.write(raw)
            index.append(
                {
                    "name": name,
                    "shape": list(arr.shape),
                    "dtype": "f32",
                    "offset_bytes": offset,
                    "size_bytes": len(raw),
                }
            )
            offset += len(raw)
    return index


def make_golden(cfg: M.ModelConfig, seed: int, n_prompts: int = 3, n_steps: int = 8) -> dict:
    """Greedy-decode a few fixed prompts with the *python* model.

    The rust integration test (rust/tests/integration_pjrt.rs) replays
    the same prompts through the compiled executables and asserts
    token-exact agreement — the cross-language correctness signal for
    the whole AOT bridge.
    """
    import numpy as np

    rng = np.random.default_rng(seed + 1234)
    prompts = [
        list(map(int, rng.integers(1, cfg.vocab_size, int(n))))
        for n in rng.integers(4, min(24, cfg.max_seq // 2), n_prompts)
    ]
    params = M.init_params(cfg, seed=seed)
    expected = []
    for p in prompts:
        toks = list(p)
        gen = []
        for _ in range(n_steps):
            logits = M.ref_forward(
                params, cfg, jnp.asarray(np.asarray(toks, np.int32)[None])
            )
            nxt = int(jnp.argmax(logits[0, -1]))
            gen.append(nxt)
            toks.append(nxt)
        expected.append(gen)
    return {"prompts": prompts, "steps": n_steps, "expected": expected}


DECODE_INPUTS = ["tokens", "block_tables", "context_lens", "slot_mapping", "k_cache", "v_cache"]
PREFILL_INPUTS = ["tokens", "prompt_lens", "slot_mapping", "k_cache", "v_cache"]
OUTPUTS = ["logits", "k_cache", "v_cache"]


def build(
    cfg: M.ModelConfig,
    out_dir: pathlib.Path,
    *,
    seed: int = 0,
    decode_buckets: Sequence[int] = DECODE_BUCKETS,
    prefill_buckets: Sequence[Tuple[int, int]] = PREFILL_BUCKETS,
) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    executables = []
    for b in decode_buckets:
        fname = f"decode_b{b}.hlo.txt"
        text = lower_decode(cfg, b)
        (out_dir / fname).write_text(text)
        executables.append(
            {
                "kind": "decode",
                "batch": b,
                "file": fname,
                "inputs": DECODE_INPUTS + list(M.WEIGHT_ORDER),
                "outputs": OUTPUTS,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            }
        )
        print(f"  wrote {fname} ({len(text)} chars)")
    for b, s in prefill_buckets:
        fname = f"prefill_b{b}_s{s}.hlo.txt"
        text = lower_prefill(cfg, b, s)
        (out_dir / fname).write_text(text)
        executables.append(
            {
                "kind": "prefill",
                "batch": b,
                "seq": s,
                "file": fname,
                "inputs": PREFILL_INPUTS + list(M.WEIGHT_ORDER),
                "outputs": OUTPUTS,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            }
        )
        print(f"  wrote {fname} ({len(text)} chars)")

    weights = dump_weights(cfg, out_dir, seed)
    golden = make_golden(cfg, seed)
    (out_dir / "golden.json").write_text(json.dumps(golden, indent=2))
    print(f"  wrote golden.json ({len(golden['prompts'])} prompts)")
    manifest = {
        "format_version": 1,
        "model": cfg.to_json(),
        "seed": seed,
        "weights": {"file": "weights.bin", "tensors": weights},
        "executables": executables,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"  wrote manifest.json ({len(executables)} executables)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--preset", default="tiny-opt", choices=sorted(PRESETS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--pallas-matmul",
        action="store_true",
        help="route linear-layer GEMMs through the Pallas kernel too "
        "(fidelity mode; ~40x slower on CPU — see EXPERIMENTS.md §Perf)",
    )
    ap.add_argument(
        "--decode-buckets",
        type=lambda s: tuple(int(x) for x in s.split(",")),
        default=DECODE_BUCKETS,
    )
    ap.add_argument(
        "--prefill-buckets",
        type=lambda s: tuple(
            (int(b), int(sq)) for b, sq in (p.split("x") for p in s.split(","))
        ),
        default=PREFILL_BUCKETS,
        help="comma-separated BxS pairs, e.g. 1x64,4x64",
    )
    args = ap.parse_args()
    if args.pallas_matmul:
        M.USE_PALLAS_MATMUL = True
    cfg = PRESETS[args.preset]
    out_dir = pathlib.Path(args.out)
    print(f"AOT-lowering {cfg.name} -> {out_dir}")
    build(
        cfg,
        out_dir,
        seed=args.seed,
        decode_buckets=args.decode_buckets,
        prefill_buckets=args.prefill_buckets,
    )


if __name__ == "__main__":
    main()
