"""L2: OPT-style decoder-only transformer over a paged KV cache.

This is the JAX compute graph the rust coordinator drives. Two entry
points are AOT-lowered per (batch/seq) bucket by ``aot.py``:

- ``prefill(...)``  — process whole (padded) prompts with the Pallas
  flash-attention kernel, scatter the produced K/V into the paged cache
  through ``slot_mapping``, return last-prompt-token logits.
- ``decode_step(...)`` — one autoregressive step for a batch: write the
  current token's K/V into the cache, run the Pallas paged-attention
  kernel (the paper's hot spot), return next-token logits.

The paged-cache contract matches ``rust/src/kvcache``: the cache is a
slab of ``num_blocks * block_size`` token slots per layer/head; rust owns
the block tables and slot mappings; *block 0 is reserved as a dummy
scratch block* so padded batch rows can harmlessly write to slot 0.

Architecture (OPT family, the paper's main subjects): learned positional
embeddings, pre-LayerNorm blocks, ReLU FFN with 4x expansion, tied
embedding/LM head. All linear projections go through the Pallas blocked
``matmul`` kernel so the L1 kernels lower into the same HLO the rust
runtime executes.

Weights are everywhere float32 (CPU PJRT path); the H100 simulator in
rust models the paper's fp16 deployments independently.
"""

from __future__ import annotations

import dataclasses
import math
import os
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.flash_attention import flash_attention
from .kernels.matmul import matmul as pallas_matmul
from .kernels.paged_attention import paged_decode_attention

# Perf knob (EXPERIMENTS.md §Perf, L2): the attention kernels — the
# paper's hot spot — are ALWAYS the Pallas implementations; the linear
# projections default to XLA's native dot, which the CPU backend executes
# ~40x faster than an interpret-mode Pallas loop nest. Set
# MEMGAP_PALLAS_MATMUL=1 (or aot.py --pallas-matmul) to route the GEMMs
# through the Pallas kernel as well (kernel-in-the-loop fidelity mode).
USE_PALLAS_MATMUL = os.environ.get("MEMGAP_PALLAS_MATMUL", "0") == "1"


def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    if USE_PALLAS_MATMUL:
        return pallas_matmul(a, b)
    return jnp.matmul(a, b)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture description (mirrored by rust models::spec)."""

    name: str = "tiny-opt"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 8
    vocab_size: int = 8192
    ffn_mult: int = 4
    max_seq: int = 512
    # paged KV cache geometry
    block_size: int = 16
    num_blocks: int = 256  # total physical blocks (block 0 reserved)
    max_blocks_per_seq: int = 32

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ffn(self) -> int:
        return self.ffn_mult * self.d_model

    @property
    def num_slots(self) -> int:
        return self.num_blocks * self.block_size

    def param_count(self) -> int:
        d, f, v, L = self.d_model, self.d_ffn, self.vocab_size, self.n_layers
        per_layer = 4 * d * d + 4 * d + 2 * d * f + d + f + 4 * d
        return v * d + self.max_seq * d + L * per_layer + 2 * d

    def to_json(self) -> Dict[str, Any]:
        out = dataclasses.asdict(self)
        out["head_dim"] = self.head_dim
        out["d_ffn"] = self.d_ffn
        out["num_slots"] = self.num_slots
        out["param_count"] = self.param_count()
        return out


# Deterministic weight ordering shared with artifacts/weights.bin and the
# rust runtime (runtime/weights.rs). Layer tensors are stacked on axis 0.
WEIGHT_ORDER: List[str] = [
    "embed",  # [V, d]
    "pos_embed",  # [max_seq, d]
    "ln1_g", "ln1_b",  # [L, d]
    "wq", "wk", "wv", "wo",  # [L, d, d]
    "bq", "bk", "bv", "bo",  # [L, d]
    "ln2_g", "ln2_b",  # [L, d]
    "w1", "b1",  # [L, d, f], [L, f]
    "w2", "b2",  # [L, f, d], [L, d]
    "lnf_g", "lnf_b",  # [d]
]


def weight_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    d, f, v, L, s = cfg.d_model, cfg.d_ffn, cfg.vocab_size, cfg.n_layers, cfg.max_seq
    return {
        "embed": (v, d),
        "pos_embed": (s, d),
        "ln1_g": (L, d), "ln1_b": (L, d),
        "wq": (L, d, d), "wk": (L, d, d), "wv": (L, d, d), "wo": (L, d, d),
        "bq": (L, d), "bk": (L, d), "bv": (L, d), "bo": (L, d),
        "ln2_g": (L, d), "ln2_b": (L, d),
        "w1": (L, d, f), "b1": (L, f),
        "w2": (L, f, d), "b2": (L, d),
        "lnf_g": (d,), "lnf_b": (d,),
    }


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """GPT-2-style init: N(0, 0.02) matrices, zero biases, unit LN gains."""
    shapes = weight_shapes(cfg)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(WEIGHT_ORDER))
    params: Dict[str, jnp.ndarray] = {}
    for key, name in zip(keys, WEIGHT_ORDER):
        shape = shapes[name]
        if name.endswith("_g"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith("_b") or name.startswith("b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            scale = 0.02
            if name in ("wo", "w2"):  # residual-branch scaling
                scale = 0.02 / math.sqrt(2 * cfg.n_layers)
            params[name] = scale * jax.random.normal(key, shape, jnp.float32)
    return params


def _layer_norm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


def _linear(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """[..., d_in] @ [d_in, d_out] through the Pallas matmul kernel."""
    lead = x.shape[:-1]
    flat = x.reshape((-1, x.shape[-1]))
    out = matmul(flat, w) + b
    return out.reshape(lead + (w.shape[1],))


def _split_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads)


def _scatter_kv(
    cache: jnp.ndarray,  # [H, slots, Dh]
    new: jnp.ndarray,  # [H, N, Dh]
    slots: jnp.ndarray,  # [N] int32
) -> jnp.ndarray:
    return cache.at[:, slots, :].set(new)


def decode_step(
    params: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # [B] int32
    block_tables: jnp.ndarray,  # [B, MB] int32
    context_lens: jnp.ndarray,  # [B] int32, INCLUDING the current token
    slot_mapping: jnp.ndarray,  # [B] int32, slot for the current token's K/V
    k_cache: jnp.ndarray,  # [L, H, slots, Dh]
    v_cache: jnp.ndarray,  # [L, H, slots, Dh]
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decode step. Returns (logits [B, V], k_cache', v_cache')."""
    b = tokens.shape[0]
    h, dh = cfg.n_heads, cfg.head_dim
    positions = jnp.clip(context_lens - 1, 0, cfg.max_seq - 1)

    x = params["embed"][tokens] + params["pos_embed"][positions]  # [B, d]
    for l in range(cfg.n_layers):
        res = x
        xn = _layer_norm(x, params["ln1_g"][l], params["ln1_b"][l])
        q = _linear(xn, params["wq"][l], params["bq"][l]) * (1.0 / math.sqrt(dh))
        k = _linear(xn, params["wk"][l], params["bk"][l])
        v = _linear(xn, params["wv"][l], params["bv"][l])
        # [B, d] -> [H, B, Dh] for the cache scatter.
        k_h = k.reshape(b, h, dh).transpose(1, 0, 2)
        v_h = v.reshape(b, h, dh).transpose(1, 0, 2)
        k_cache = k_cache.at[l].set(_scatter_kv(k_cache[l], k_h, slot_mapping))
        v_cache = v_cache.at[l].set(_scatter_kv(v_cache[l], v_h, slot_mapping))
        attn = paged_decode_attention(
            q.reshape(b, h, dh),
            k_cache[l],
            v_cache[l],
            block_tables,
            context_lens,
            block_size=cfg.block_size,
            scale=1.0,  # q pre-scaled above
        )  # [B, H, Dh]
        x = res + _linear(
            attn.reshape(b, cfg.d_model), params["wo"][l], params["bo"][l]
        )
        res = x
        xn = _layer_norm(x, params["ln2_g"][l], params["ln2_b"][l])
        hdn = jax.nn.relu(_linear(xn, params["w1"][l], params["b1"][l]))
        x = res + _linear(hdn, params["w2"][l], params["b2"][l])

    x = _layer_norm(x, params["lnf_g"], params["lnf_b"])
    logits = matmul(x, params["embed"].T)  # tied LM head, [B, V]
    return logits, k_cache, v_cache


def prefill(
    params: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # [B, S] int32 (padded with 0 past prompt_lens)
    prompt_lens: jnp.ndarray,  # [B] int32
    slot_mapping: jnp.ndarray,  # [B, S] int32 (pads -> slot 0, the dummy block)
    k_cache: jnp.ndarray,  # [L, H, slots, Dh]
    v_cache: jnp.ndarray,  # [L, H, slots, Dh]
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Process prompts, fill the cache, return last-token logits [B, V].

    Padded positions attend causally so real tokens never see them (pads
    sit *after* the prompt), and their K/V lands in the reserved dummy
    block, so the cache stays clean.
    """
    b, s = tokens.shape
    h, dh = cfg.n_heads, cfg.head_dim
    positions = jnp.clip(jnp.arange(s, dtype=jnp.int32), 0, cfg.max_seq - 1)

    x = params["embed"][tokens] + params["pos_embed"][positions][None, :, :]
    flat_slots = slot_mapping.reshape(-1)  # [B*S]
    for l in range(cfg.n_layers):
        res = x
        xn = _layer_norm(x, params["ln1_g"][l], params["ln1_b"][l])
        q = _linear(xn, params["wq"][l], params["bq"][l])
        k = _linear(xn, params["wk"][l], params["bk"][l])
        v = _linear(xn, params["wv"][l], params["bv"][l])
        qh = _split_heads(q, h)  # [B, S, H, Dh]
        kh = _split_heads(k, h)
        vh = _split_heads(v, h)
        # Scatter this layer's K/V into the paged cache.
        k_flat = kh.reshape(b * s, h, dh).transpose(1, 0, 2)  # [H, B*S, Dh]
        v_flat = vh.reshape(b * s, h, dh).transpose(1, 0, 2)
        k_cache = k_cache.at[l].set(_scatter_kv(k_cache[l], k_flat, flat_slots))
        v_cache = v_cache.at[l].set(_scatter_kv(v_cache[l], v_flat, flat_slots))
        attn = flash_attention(qh, kh, vh, causal=True)  # [B, S, H, Dh]
        x = res + _linear(
            attn.reshape(b, s, cfg.d_model), params["wo"][l], params["bo"][l]
        )
        res = x
        xn = _layer_norm(x, params["ln2_g"][l], params["ln2_b"][l])
        hdn = jax.nn.relu(_linear(xn, params["w1"][l], params["b1"][l]))
        x = res + _linear(hdn, params["w2"][l], params["b2"][l])

    x = _layer_norm(x, params["lnf_g"], params["lnf_b"])
    # Gather each sequence's last real token.
    last = jnp.clip(prompt_lens - 1, 0, s - 1)  # [B]
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0, :]  # [B, d]
    logits = matmul(x_last, params["embed"].T)  # [B, V]
    return logits, k_cache, v_cache


def ref_forward(
    params: Dict[str, jnp.ndarray], cfg: ModelConfig, tokens: jnp.ndarray
) -> jnp.ndarray:
    """Non-paged oracle: full-sequence forward returning [B, S, V] logits.

    Uses plain jnp ops end-to-end (no Pallas, no cache) — the ground truth
    for prefill/decode equivalence tests.
    """
    from .kernels.ref import ref_attention

    b, s = tokens.shape
    h = cfg.n_heads
    x = params["embed"][tokens] + params["pos_embed"][jnp.arange(s)][None]
    for l in range(cfg.n_layers):
        res = x
        xn = _layer_norm(x, params["ln1_g"][l], params["ln1_b"][l])
        q = xn @ params["wq"][l] + params["bq"][l]
        k = xn @ params["wk"][l] + params["bk"][l]
        v = xn @ params["wv"][l] + params["bv"][l]
        attn = ref_attention(
            _split_heads(q, h), _split_heads(k, h), _split_heads(v, h), causal=True
        )
        x = res + attn.reshape(b, s, cfg.d_model) @ params["wo"][l] + params["bo"][l]
        res = x
        xn = _layer_norm(x, params["ln2_g"][l], params["ln2_b"][l])
        x = (
            res
            + jax.nn.relu(xn @ params["w1"][l] + params["b1"][l]) @ params["w2"][l]
            + params["b2"][l]
        )
    x = _layer_norm(x, params["lnf_g"], params["lnf_b"])
    return x @ params["embed"].T
