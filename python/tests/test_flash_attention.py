"""Pallas flash (tiled) attention vs the jnp oracle, hypothesis-swept."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_attention as fa
from compile.kernels import ref

RNG = np.random.default_rng(99)


def _qkv(b, s, t, h, d, dtype=jnp.float32):
    def r(shape):
        return jnp.asarray(RNG.standard_normal(shape).astype(np.float32), dtype=dtype)

    return r((b, s, h, d)), r((b, t, h, d)), r((b, t, h, d))


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    s=st.integers(1, 50),
    h=st.integers(1, 3),
    d=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
)
def test_self_attention_matches_ref(b, s, h, d, causal):
    q, k, v = _qkv(b, s, s, h, d)
    got = fa.flash_attention(q, k, v, causal=causal)
    want = ref.ref_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    s=st.integers(1, 24),
    extra=st.integers(1, 40),
    d=st.sampled_from([8, 16]),
)
def test_cross_length_causal_offset(s, extra, d):
    """Query block shorter than KV (cached prefix): offset masking."""
    t = s + extra
    q, k, v = _qkv(2, s, t, 2, d)
    got = fa.flash_attention(q, k, v, causal=True)
    want = ref.ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("block_q,block_k", [(8, 8), (8, 32), (32, 8), (64, 64)])
def test_tile_size_invariance(block_q, block_k):
    q, k, v = _qkv(2, 45, 45, 2, 16)
    got = fa.flash_attention(q, k, v, causal=True, block_q=block_q, block_k=block_k)
    want = ref.ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_single_token_equals_softmax_v():
    """S=1, causal: output must be V row 0 exactly (softmax over 1 key)."""
    q, k, v = _qkv(1, 1, 1, 2, 16)
    got = fa.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got)[0, 0], np.asarray(v)[0, 0], rtol=1e-6)


def test_scale_override():
    q, k, v = _qkv(1, 12, 12, 2, 16)
    got = fa.flash_attention(q, k, v, causal=False, scale=0.5)
    want = ref.ref_attention(q, k, v, causal=False, scale=0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_bf16_close_to_f32_ref():
    q, k, v = _qkv(1, 33, 33, 2, 16, dtype=jnp.bfloat16)
    got = np.asarray(fa.flash_attention(q, k, v, causal=True), dtype=np.float32)
    want = np.asarray(ref.ref_attention(q, k, v, causal=True), dtype=np.float32)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


def test_cost_model_prefill_is_compute_leaning():
    """Prefill attention AI grows with seq len (paper: prefill compute-bound)."""
    h, d = 32, 64
    ai_small = fa.flops(1, 64, 64, h, d) / fa.io_bytes(1, 64, 64, h, d)
    ai_large = fa.flops(1, 2048, 2048, h, d) / fa.io_bytes(1, 2048, 2048, h, d)
    assert ai_large > ai_small
