"""AOT pipeline: manifest schema, HLO text validity, weights layout."""

import json
import pathlib

import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    cfg = aot.PRESETS["micro-opt"]
    manifest = aot.build(
        cfg, out, seed=0, decode_buckets=(1, 2), prefill_buckets=((1, 16),)
    )
    return cfg, out, manifest


def test_manifest_schema(built):
    cfg, out, manifest = built
    on_disk = json.loads((out / "manifest.json").read_text())
    assert on_disk == manifest
    assert manifest["format_version"] == 1
    m = manifest["model"]
    assert m["name"] == cfg.name
    assert m["head_dim"] == cfg.head_dim
    assert m["num_slots"] == cfg.num_blocks * cfg.block_size
    kinds = {(e["kind"], e.get("batch"), e.get("seq")) for e in manifest["executables"]}
    assert ("decode", 1, None) in kinds
    assert ("decode", 2, None) in kinds
    assert ("prefill", 1, 16) in kinds


def test_hlo_text_is_parseable_entry(built):
    _, out, manifest = built
    for e in manifest["executables"]:
        text = (out / e["file"]).read_text()
        assert "HloModule" in text
        assert "ENTRY" in text
        # return_tuple=True: root of the entry computation is a tuple of 3.
        assert "tuple(" in text.replace(") tuple", " tuple")


def test_weights_bin_layout(built):
    cfg, out, manifest = built
    tensors = manifest["weights"]["tensors"]
    names = [t["name"] for t in tensors]
    assert names == list(M.WEIGHT_ORDER)
    data = (out / "weights.bin").read_bytes()
    assert len(data) == sum(t["size_bytes"] for t in tensors)
    assert len(data) == 4 * cfg.param_count()
    # Offsets are contiguous and sorted.
    off = 0
    for t in tensors:
        assert t["offset_bytes"] == off
        assert t["size_bytes"] == 4 * int(np.prod(t["shape"]))
        off += t["size_bytes"]


def test_weights_reproducible_from_seed(built):
    cfg, out, manifest = built
    params = M.init_params(cfg, seed=manifest["seed"])
    data = (out / "weights.bin").read_bytes()
    t = manifest["weights"]["tensors"][0]  # embed
    got = np.frombuffer(
        data[t["offset_bytes"] : t["offset_bytes"] + t["size_bytes"]], np.float32
    ).reshape(t["shape"])
    np.testing.assert_array_equal(got, np.asarray(params["embed"]))


def test_input_signature_matches_contract(built):
    _, _, manifest = built
    for e in manifest["executables"]:
        base = aot.DECODE_INPUTS if e["kind"] == "decode" else aot.PREFILL_INPUTS
        assert e["inputs"] == base + list(M.WEIGHT_ORDER)
        assert e["outputs"] == ["logits", "k_cache", "v_cache"]


def test_executables_deterministic_sha(built):
    cfg, out, manifest = built
    for e in manifest["executables"]:
        import hashlib

        text = (out / e["file"]).read_text()
        assert hashlib.sha256(text.encode()).hexdigest() == e["sha256"]


def test_parameter_count_in_hlo(built):
    """Each executable must declare exactly base-inputs + 21 weights params."""
    _, out, manifest = built
    for e in manifest["executables"]:
        text = (out / e["file"]).read_text()
        entry = text.split("ENTRY")[-1]
        n_params = entry.count("parameter(")
        assert n_params == len(e["inputs"])
