"""Golden values for the kernel cost models.

``rust/src/gpusim/kernels.rs`` mirrors these formulas; the same golden
numbers are asserted there (tests `golden_matches_python_*`). If either
side changes, both tests fail — keeping the simulator and the Pallas
kernels describing the same IO schedule.
"""

from compile.kernels import flash_attention as fa
from compile.kernels import matmul as mm
from compile.kernels import paged_attention as pa

# --- paged decode attention ------------------------------------------------


def test_paged_attention_golden():
    # OPT-1.3B-like: 32 heads, 64 head_dim, ShareGPT mean ctx 338, fp16.
    got_bytes = pa.io_bytes(1, 32, 64, [338], block_size=16, dtype_bytes=2)
    got_flops = pa.flops(1, 32, 64, [338])
    assert got_bytes == 2 * 32 * 352 * 64 * 2 + 2 * 1 * 32 * 64 * 2
    assert got_bytes == 2_891_776
    assert got_flops == 4 * 32 * 338 * 64
    assert got_flops == 2768896


def test_paged_attention_batch_scaling_golden():
    b = 256
    got_bytes = pa.io_bytes(b, 32, 64, [338] * b, block_size=16, dtype_bytes=2)
    got_flops = pa.flops(b, 32, 64, [338] * b)
    assert got_bytes == 256 * (2 * 32 * 352 * 64 * 2) + 2 * 256 * 32 * 64 * 2
    assert got_bytes == 740_294_656
    assert got_flops == 256 * 2768896


def test_paged_attention_ai_band():
    ai = pa.flops(64, 32, 64, [338] * 64) / pa.io_bytes(
        64, 32, 64, [338] * 64, block_size=16
    )
    assert 0.4 < ai < 1.2  # paper Fig. 1: 0.5..1 FLOP/byte


# --- matmul ------------------------------------------------------------------


def test_matmul_golden():
    # decode QKV projection, OPT-1.3B: [B, 2048] @ [2048, 2048], fp16
    assert mm.flops(1, 2048, 2048) == 2 * 2048 * 2048
    assert mm.io_bytes(1, 2048, 2048, block_m=32, block_n=32, dtype_bytes=2) == (
        1 * 2048 * 64 * 2 + 2048 * 2048 * 1 * 2 + 1 * 2048 * 2
    )
    assert mm.io_bytes(1, 2048, 2048, block_m=32, block_n=32, dtype_bytes=2) == 8654848


def test_matmul_ai_growth_golden():
    d = 2048
    ai1 = mm.flops(1, d, d) / mm.io_bytes(1, d, d)
    ai512 = mm.flops(512, d, d) / mm.io_bytes(512, d, d)
    # Batching amortizes the weight read; the tiled model caps AI at the
    # tile-bound value (~bm*bn/(bm+bn) MACs per element), ~16x here.
    assert ai512 > 10 * ai1


# --- flash (prefill) attention ----------------------------------------------


def test_flash_attention_golden():
    # one prompt, 161 tokens (ShareGPT mean input), 32 heads, d 64
    f = fa.flops(1, 161, 161, 32, 64, causal=True)
    assert f == 4 * 32 * ((161 * 161) // 2 + 161 // 2) * 64
    by = fa.io_bytes(1, 161, 161, 32, 64, block_q=32, dtype_bytes=2)
    n_tiles = (161 + 31) // 32
    assert by == 2 * 32 * 161 * 64 * 2 + 2 * 32 * 161 * 64 * 2 * n_tiles
