"""Pallas blocked matmul vs the jnp oracle, hypothesis-swept."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as mm
from compile.kernels import ref

RNG = np.random.default_rng(1234)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
)
def test_matmul_matches_ref_f32(m, k, n):
    a = _rand((m, k), jnp.float32)
    b = _rand((k, n), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(mm.matmul(a, b)),
        np.asarray(ref.ref_matmul(a, b)),
        rtol=2e-5,
        atol=2e-5,
    )


@settings(max_examples=8, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
)
def test_matmul_matches_ref_bf16(m, k, n):
    a = _rand((m, k), jnp.bfloat16)
    b = _rand((k, n), jnp.bfloat16)
    got = np.asarray(mm.matmul(a, b), dtype=np.float32)
    want = np.asarray(ref.ref_matmul(a, b), dtype=np.float32)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("block", [8, 16, 32, 64])
def test_matmul_block_size_invariance(block):
    a = _rand((50, 37), jnp.float32)
    b = _rand((37, 41), jnp.float32)
    got = mm.matmul(a, b, block_m=block, block_n=block, block_k=block)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.ref_matmul(a, b)), rtol=2e-5, atol=2e-5
    )


def test_matmul_identity():
    a = _rand((17, 17), jnp.float32)
    eye = jnp.eye(17, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(mm.matmul(a, eye)), np.asarray(a), rtol=1e-6)


def test_matmul_zero():
    a = _rand((9, 13), jnp.float32)
    z = jnp.zeros((13, 5), jnp.float32)
    assert np.all(np.asarray(mm.matmul(a, z)) == 0.0)


def test_cost_model_gemv_vs_gemm_ai():
    """Matmul arithmetic intensity must grow with batch (paper Fig. 1)."""
    d = 2048
    ai = []
    for b in (1, 32, 512):
        ai.append(mm.flops(b, d, d) / mm.io_bytes(b, d, d))
    # AI grows with batch up to the tile-bound ceiling, then flattens.
    assert ai[0] < ai[1]
    assert ai[2] >= 0.9 * ai[1]
    # GEMV AI is ~1 FLOP/byte at fp16, deep in the memory-bound regime.
    assert ai[0] < 2.0
