"""Pallas paged decode attention vs the jnp oracle (the paper's hot spot)."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import paged_attention as pa
from compile.kernels import ref

RNG = np.random.default_rng(7)


def _setup(b, h, d, block_size, num_blocks, max_blocks, ctx_lens, dtype=jnp.float32):
    slots = num_blocks * block_size

    def r(shape):
        return jnp.asarray(RNG.standard_normal(shape).astype(np.float32), dtype=dtype)

    q = r((b, h, d))
    kc = r((h, slots, d))
    vc = r((h, slots, d))
    # Random (possibly shared) physical blocks — the oracle only reads the
    # first ctx_len positions, so collisions are harmless for reads.
    bt = jnp.asarray(RNG.integers(0, num_blocks, size=(b, max_blocks)), dtype=jnp.int32)
    cl = jnp.asarray(np.asarray(ctx_lens, dtype=np.int32))
    return q, kc, vc, bt, cl


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 5),
    h=st.integers(1, 3),
    d=st.sampled_from([8, 16, 32]),
    block_size=st.sampled_from([4, 8, 16]),
    data=st.data(),
)
def test_matches_ref(b, h, d, block_size, data):
    max_blocks = 6
    num_blocks = 16
    max_len = max_blocks * block_size
    ctx_lens = data.draw(
        st.lists(st.integers(1, max_len), min_size=b, max_size=b)
    )
    q, kc, vc, bt, cl = _setup(b, h, d, block_size, num_blocks, max_blocks, ctx_lens)
    got = pa.paged_decode_attention(q, kc, vc, bt, cl, block_size=block_size)
    want = ref.ref_paged_decode_attention(q, kc, vc, bt, cl, block_size=block_size)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_ctx_len_one_reads_single_slot():
    """ctx=1: output must equal V at the first slot of the first block."""
    b, h, d, bs = 1, 2, 16, 8
    q, kc, vc, bt, cl = _setup(b, h, d, bs, 8, 4, [1])
    got = pa.paged_decode_attention(q, kc, vc, bt, cl, block_size=bs)
    slot = int(bt[0, 0]) * bs
    np.testing.assert_allclose(
        np.asarray(got)[0], np.asarray(vc)[:, slot, :], rtol=1e-5, atol=1e-6
    )


def test_partial_tail_block_masking():
    """A ctx that ends mid-block must ignore the block's tail slots."""
    b, h, d, bs = 2, 2, 16, 8
    q, kc, vc, bt, cl = _setup(b, h, d, bs, 8, 4, [5, 13])
    got = pa.paged_decode_attention(q, kc, vc, bt, cl, block_size=bs)
    want = ref.ref_paged_decode_attention(q, kc, vc, bt, cl, block_size=bs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    # Corrupting slots beyond ctx_len must not change the result.
    kc2 = kc.at[:, int(bt[0, 0]) * bs + 5 :, :].set(1e6)
    got2 = pa.paged_decode_attention(q, kc2, vc, bt, cl, block_size=bs)
    np.testing.assert_allclose(np.asarray(got2)[0], np.asarray(got)[0], rtol=2e-5)


def test_block_table_indirection():
    """Permuting physical blocks while fixing the table is a no-op."""
    b, h, d, bs, nb, mb = 1, 1, 8, 4, 8, 4
    q, kc, vc, _, cl = _setup(b, h, d, bs, nb, mb, [16])
    bt1 = jnp.asarray([[0, 1, 2, 3]], dtype=jnp.int32)
    out1 = pa.paged_decode_attention(q, kc, vc, bt1, cl, block_size=bs)

    # Move logical block i to physical block perm[i]; permute cache rows.
    perm = np.array([5, 2, 7, 0], dtype=np.int32)
    kc2 = np.array(kc)
    vc2 = np.array(vc)
    for logical, phys in enumerate(perm):
        kc2[:, phys * bs : (phys + 1) * bs, :] = np.asarray(kc)[
            :, logical * bs : (logical + 1) * bs, :
        ]
        vc2[:, phys * bs : (phys + 1) * bs, :] = np.asarray(vc)[
            :, logical * bs : (logical + 1) * bs, :
        ]
    bt2 = jnp.asarray(perm[None, :])
    out2 = pa.paged_decode_attention(
        q, jnp.asarray(kc2), jnp.asarray(vc2), bt2, cl, block_size=bs
    )
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=2e-5, atol=2e-5)


def test_uniform_values_give_value_mean():
    """With identical V everywhere, output is V regardless of scores."""
    b, h, d, bs = 2, 2, 8, 4
    q, kc, _, bt, cl = _setup(b, h, d, bs, 8, 4, [7, 16])
    vc = jnp.ones_like(kc) * 3.5
    got = pa.paged_decode_attention(q, kc, vc, bt, cl, block_size=bs)
    np.testing.assert_allclose(np.asarray(got), 3.5, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    b, h, d, bs = 2, 2, 16, 8
    q, kc, vc, bt, cl = _setup(b, h, d, bs, 8, 4, [9, 21], dtype=dtype)
    got = np.asarray(
        pa.paged_decode_attention(q, kc, vc, bt, cl, block_size=bs), dtype=np.float32
    )
    want = np.asarray(
        ref.ref_paged_decode_attention(q, kc, vc, bt, cl, block_size=bs),
        dtype=np.float32,
    )
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_cost_model_constant_arithmetic_intensity():
    """The paper's central claim: decode-attention AI is ~constant in B."""
    h, d, bs = 32, 64, 16
    ais = []
    for b in (1, 32, 512):
        ctx = [338] * b
        ai = pa.flops(b, h, d, ctx) / pa.io_bytes(b, h, d, ctx, block_size=bs)
        ais.append(ai)
    # All within a few percent of each other, and in the paper's 0.5..1.5 band.
    assert max(ais) / min(ais) < 1.1
    assert 0.25 <= min(ais) and max(ais) <= 2.0
