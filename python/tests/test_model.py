"""L2 model: paged prefill + decode must equal the non-paged oracle.

This is the model-level correctness signal: if the paged cache plumbing
(block tables, slot mappings, scatter, padding rows, dummy block 0) were
wrong anywhere, greedy decoding would diverge from ``ref_forward``.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from compile import model as M

CFG = M.ModelConfig(
    name="test",
    n_layers=2,
    d_model=64,
    n_heads=4,
    vocab_size=128,
    max_seq=64,
    block_size=8,
    num_blocks=32,
    max_blocks_per_seq=8,
)
RNG = np.random.default_rng(42)


def _block_tables(cfg, batch):
    """Disjoint block tables; block 0 stays reserved as the dummy block."""
    bt = np.zeros((batch, cfg.max_blocks_per_seq), np.int32)
    nxt = 1
    for i in range(batch):
        bt[i] = np.arange(nxt, nxt + cfg.max_blocks_per_seq)
        nxt += cfg.max_blocks_per_seq
    assert nxt <= cfg.num_blocks
    return bt


def _slot(bt_row, pos, block_size):
    return int(bt_row[pos // block_size]) * block_size + pos % block_size


def _prefill_inputs(cfg, prompt_lens, pad_to):
    batch = len(prompt_lens)
    bt = _block_tables(cfg, batch)
    tokens = np.zeros((batch, pad_to), np.int32)
    slots = np.zeros((batch, pad_to), np.int32)  # pads -> dummy slot 0
    for i, n in enumerate(prompt_lens):
        tokens[i, :n] = RNG.integers(1, cfg.vocab_size, n)
        for j in range(n):
            slots[i, j] = _slot(bt[i], j, cfg.block_size)
    return tokens, slots, bt


def _fresh_caches(cfg):
    shape = (cfg.n_layers, cfg.n_heads, cfg.num_slots, cfg.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=3)


@pytest.mark.parametrize("prompt_lens", [[6], [5, 9], [1, 16, 11]])
def test_prefill_matches_ref(params, prompt_lens):
    tokens, slots, _ = _prefill_inputs(CFG, prompt_lens, pad_to=16)
    kc, vc = _fresh_caches(CFG)
    logits, _, _ = M.prefill(
        params,
        CFG,
        jnp.asarray(tokens),
        jnp.asarray(np.asarray(prompt_lens, np.int32)),
        jnp.asarray(slots),
        kc,
        vc,
    )
    for i, n in enumerate(prompt_lens):
        want = M.ref_forward(params, CFG, jnp.asarray(tokens[i : i + 1, :n]))
        np.testing.assert_allclose(
            np.asarray(logits)[i], np.asarray(want)[0, -1], rtol=3e-4, atol=3e-4
        )


def test_greedy_decode_matches_ref(params):
    """Prefill then 6 greedy decode steps; per-step logits vs the oracle."""
    prompt_lens = [5, 9]
    tokens, slots, bt = _prefill_inputs(CFG, prompt_lens, pad_to=16)
    kc, vc = _fresh_caches(CFG)
    logits, kc, vc = M.prefill(
        params,
        CFG,
        jnp.asarray(tokens),
        jnp.asarray(np.asarray(prompt_lens, np.int32)),
        jnp.asarray(slots),
        kc,
        vc,
    )
    seqs = [list(tokens[i, :n]) for i, n in enumerate(prompt_lens)]
    ctx = np.asarray(prompt_lens, np.int32)
    nxt = np.argmax(np.asarray(logits), -1).astype(np.int32)
    for _ in range(6):
        for i in range(len(seqs)):
            seqs[i].append(int(nxt[i]))
        ctx = ctx + 1
        sm = np.asarray(
            [_slot(bt[i], int(ctx[i]) - 1, CFG.block_size) for i in range(len(seqs))],
            np.int32,
        )
        logits, kc, vc = M.decode_step(
            params,
            CFG,
            jnp.asarray(nxt),
            jnp.asarray(bt),
            jnp.asarray(ctx),
            jnp.asarray(sm),
            kc,
            vc,
        )
        for i, s in enumerate(seqs):
            want = M.ref_forward(params, CFG, jnp.asarray(np.asarray(s, np.int32)[None]))
            np.testing.assert_allclose(
                np.asarray(logits)[i], np.asarray(want)[0, -1], rtol=3e-4, atol=3e-4
            )
        nxt = np.argmax(np.asarray(logits), -1).astype(np.int32)


def test_padded_batch_rows_do_not_disturb_real_rows(params):
    """Bucket padding contract: a dummy row (ctx=1, slots->0) must leave
    the real row's logits identical to an unpadded run."""
    prompt_lens = [7]
    tokens, slots, bt = _prefill_inputs(CFG, prompt_lens, pad_to=16)
    kc, vc = _fresh_caches(CFG)
    logits, kc, vc = M.prefill(
        params,
        CFG,
        jnp.asarray(tokens),
        jnp.asarray(np.asarray(prompt_lens, np.int32)),
        jnp.asarray(slots),
        kc,
        vc,
    )
    nxt = int(np.argmax(np.asarray(logits)[0]))

    def run_decode(batch_pad):
        toks = np.asarray([nxt] + [0] * batch_pad, np.int32)
        bts = np.concatenate([bt, np.zeros((batch_pad, CFG.max_blocks_per_seq), np.int32)])
        ctx = np.asarray([8] + [1] * batch_pad, np.int32)
        sm = np.asarray(
            [_slot(bt[0], 7, CFG.block_size)] + [0] * batch_pad, np.int32
        )
        out, _, _ = M.decode_step(
            params,
            CFG,
            jnp.asarray(toks),
            jnp.asarray(bts),
            jnp.asarray(ctx),
            jnp.asarray(sm),
            kc,
            vc,
        )
        return np.asarray(out)[0]

    unpadded = run_decode(0)
    padded = run_decode(3)
    np.testing.assert_allclose(padded, unpadded, rtol=1e-5, atol=1e-5)


def test_param_count_matches_shapes(params):
    total = sum(int(np.prod(v.shape)) for v in params.values())
    assert total == CFG.param_count()


def test_weight_order_covers_all_params(params):
    assert set(M.WEIGHT_ORDER) == set(params.keys())
    assert len(M.WEIGHT_ORDER) == len(params)
