//! Nsight-Compute-like report for the decode-attention kernel (paper
//! §V-C): roofline placement, cache hit rates and stalled cycles across
//! batch sizes and both attention backends, plus the TPU-side VMEM/MXU
//! estimates for the Pallas kernels (DESIGN.md §Hardware-Adaptation).
//!
//!     cargo run --release --example profile_attention [-- --model Llama-2-7B]

use memgap::figures::roofline_figs;
use memgap::gpusim::profiler::profile_attention;
use memgap::gpusim::roofline::{tpu_flash_attention, tpu_matmul, tpu_paged_attention};
use memgap::gpusim::GpuSpec;
use memgap::models::spec::{AttentionBackendKind, ModelSpec};
use memgap::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let spec = ModelSpec::by_name(args.get_or("model", "OPT-1.3B"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let gpu = GpuSpec::h100_64g();
    let ctx = args.usize_or("ctx", 499);
    let bmax = roofline_figs::max_batch(&gpu, &spec);

    println!("== decode attention on simulated H100 — {} (ctx {ctx}) ==", spec.name);
    println!(
        "{:>10} {:>12} {:>13} {:>12} {:>7} {:>7} {:>8} {:>8}",
        "backend", "batch", "traffic B/s", "FLOP/s", "AI", "L1 %", "L2 %", "stall %"
    );
    for backend in [
        AttentionBackendKind::XFormers,
        AttentionBackendKind::FlashAttention,
    ] {
        if backend == AttentionBackendKind::FlashAttention && !spec.flash_compatible() {
            println!("{:>10}  (incompatible: head_dim {})", "flash", spec.head_dim());
            continue;
        }
        for b in [1usize, 32, 128, bmax] {
            let p = profile_attention(&gpu, &spec, backend, b, ctx, 16);
            println!(
                "{:>10} {:>12} {:>13.2e} {:>12.2e} {:>7.2} {:>7.2} {:>8.2} {:>8.1}",
                match backend {
                    AttentionBackendKind::XFormers => "xformers",
                    AttentionBackendKind::FlashAttention => "flash",
                },
                b,
                p.mem_traffic,
                p.performance,
                p.arithmetic_intensity,
                p.l1_hit_rate,
                p.l2_hit_rate,
                p.stalled_pct
            );
        }
    }
    println!(
        "\nhardware: DRAM {:.2e} B/s | SP peak {:.2e} FLOP/s | ridge {:.1} FLOP/byte",
        gpu.dram_bw,
        gpu.peak_flops_sp,
        gpu.ridge_ai_sp()
    );

    println!("\n== TPU estimates for the Pallas kernels (interpret=True; static analysis) ==");
    let paged = tpu_paged_attention(64, 16, ctx, 4);
    let flash = tpu_flash_attention(64, 128, 128, ctx, 4);
    let mm = tpu_matmul(2048, 128, 128, 128, 4);
    for e in [paged, flash, mm] {
        println!(
            "{:<24} VMEM/program {:>8} B  HBM/program {:>10} B  MXU util {:>5.1} %  fits VMEM: {}",
            e.kernel,
            e.vmem_bytes_per_program,
            e.hbm_bytes_per_program,
            100.0 * e.mxu_utilization,
            e.fits_vmem
        );
    }
    println!("(decode attention starves the MXU exactly as it starves CUDA cores — the paper's point, translated)");
    Ok(())
}
