//! Quickstart: simulate serving OPT-1.3B on an H100 in ~30 lines.
//!
//!     cargo run --release --example quickstart
//!
//! Builds a vLLM-like engine over the analytical H100 backend, submits
//! 2x96 ShareGPT-mean requests and prints the serving metrics — the
//! paper's offline-mode methodology (§IV/§V) in miniature.

use memgap::coordinator::offline::OfflineConfig;
use memgap::models::spec::ModelSpec;

fn main() -> anyhow::Result<()> {
    // vLLM-like engine: OPT-1.3B, max batch 96 (the paper's strict-SLO
    // B_opt), paged KV cache sized from the 64 GB H100 budget.
    let mut cfg = OfflineConfig::new(ModelSpec::opt_1_3b(), 96);
    cfg.num_requests = 192; // two full waves
    let report = cfg.run()?;

    println!("== memgap quickstart: OPT-1.3B @ max batch 96 on simulated H100 ==");
    println!("completed      : {}", report.metrics.completed);
    println!(
        "throughput     : {:.0} tokens/s",
        report.metrics.throughput_tps
    );
    println!("mean ITL       : {:.2} ms", report.metrics.mean_itl * 1e3);
    println!("mean E2E       : {:.2} s", report.metrics.mean_e2e);
    println!(
        "peak KV usage  : {:.1} % of the cache",
        100.0 * report.peak_kv_usage
    );
    println!(
        "CPU-gap share  : {:.1} % of wall time",
        100.0 * report.metrics.cpu_time_frac
    );
    println!(
        "decode/prefill : {:.2} s / {:.2} s",
        report.decode_time, report.prefill_time
    );
    Ok(())
}
