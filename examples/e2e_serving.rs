//! End-to-end validation (DESIGN.md §6): load the REAL tiny-opt model
//! AOT-compiled from JAX+Pallas, and serve batched requests through the
//! full rust stack — router -> continuous batcher -> paged KV cache ->
//! PJRT CPU execution. Proves all three layers compose with python
//! nowhere on the request path.
//!
//!     make artifacts && cargo run --release --example e2e_serving
//!
//! Phase 1 drives the engine directly (offline mode, batched);
//! Phase 2 starts the TCP server and serves concurrent clients online.
//! Results are recorded in EXPERIMENTS.md.

use std::time::Instant;

use memgap::coordinator::engine::{Engine, EngineConfig};
use memgap::coordinator::server;
use memgap::runtime::{self, PjrtBackend};
use memgap::workload::{generate, WorkloadConfig};

fn main() -> anyhow::Result<()> {
    let dir = runtime::default_artifacts_dir();
    if !runtime::artifacts_available() {
        eprintln!(
            "artifacts not found in {} — run `make artifacts` first",
            dir.display()
        );
        std::process::exit(2);
    }

    println!("== Phase 1: offline batched serving over PJRT ==");
    let t0 = Instant::now();
    let backend = PjrtBackend::load(&dir)?;
    println!(
        "loaded {} ({:.1}M params, {} executables) on '{}' in {:.1}s",
        backend.manifest.model.name,
        backend.manifest.model.param_count as f64 / 1e6,
        backend.manifest.executables.len(),
        backend.platform(),
        t0.elapsed().as_secs_f64()
    );
    let (blocks, bs, mbs) = backend.kv_geometry();
    let mut cfg = EngineConfig::new(8, blocks, bs);
    cfg.max_blocks_per_seq = mbs;
    cfg.max_batched_tokens = 256;
    let mut engine = Engine::new(backend, cfg);

    // 64 requests, prompts 8..48 tokens, 24 output tokens each.
    let reqs = generate(&WorkloadConfig::offline(64, 32, 24));
    let t0 = Instant::now();
    engine.submit(&reqs);
    let report = engine.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();
    println!("completed        : {}/64", report.metrics.completed);
    println!("steps            : {}", report.steps);
    println!("wall time        : {:.2} s", wall);
    println!(
        "throughput       : {:.1} output tok/s ({:.1} total tok/s)",
        report.metrics.total_output_tokens as f64 / wall,
        (report.metrics.total_input_tokens + report.metrics.total_output_tokens) as f64 / wall
    );
    println!(
        "mean ITL         : {:.1} ms (virtual-clock)",
        report.metrics.mean_itl * 1e3
    );
    println!("peak KV usage    : {:.1} %", 100.0 * report.peak_kv_usage);
    assert_eq!(report.metrics.completed, 64, "all requests must finish");
    assert_eq!(report.metrics.total_output_tokens, 64 * 24);

    println!("\n== Phase 2: online client-server over TCP ==");
    let backend = PjrtBackend::load(&dir)?;
    let (blocks, bs, mbs) = backend.kv_geometry();
    let mut cfg = EngineConfig::new(8, blocks, bs);
    cfg.max_blocks_per_seq = mbs;
    cfg.max_batched_tokens = 256;
    let engine = Engine::new(backend, cfg);
    let addr = "127.0.0.1:8078";
    // The PJRT engine is not Send, so the server runs on THIS thread;
    // clients run in spawned threads and shut the server down when done.
    let driver = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(300));
        let t0 = Instant::now();
        let clients: Vec<_> = (0..12)
            .map(|i| {
                std::thread::spawn(move || {
                    let resp = server::client_generate(addr, 16 + (i % 4) * 8, 12).unwrap();
                    let n = resp.get("tokens").unwrap().as_arr().unwrap().len();
                    assert_eq!(n, 12, "client {i}: wrong token count");
                    n
                })
            })
            .collect();
        let total: usize = clients.into_iter().map(|c| c.join().unwrap()).sum();
        let wall = t0.elapsed().as_secs_f64();
        server::client_shutdown(addr).unwrap();
        (total, wall)
    });
    let served = server::serve(engine, addr)?;
    let (total, wall) = driver.join().unwrap();
    println!(
        "12 concurrent clients: {total} tokens in {wall:.2} s ({:.1} tok/s)",
        total as f64 / wall
    );
    println!("server served {served} requests");
    println!("\nE2E SERVING OK — three layers composed (Pallas kernels -> JAX model -> HLO -> PJRT -> rust coordinator)");
    Ok(())
}
