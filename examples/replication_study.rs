//! Replication study (paper §VI-B / Fig 13 / Table IV): compare one
//! MAX-batch instance against BCA-sized replicas under FCFS
//! time-sharing and MPS concurrent execution.
//!
//!     cargo run --release --example replication_study [-- --quick]

use memgap::bca::{self, BcaProfile, Constraints};
use memgap::coordinator::offline::OfflineConfig;
use memgap::figures::{bca_figs, roofline_figs, FigOpts};
use memgap::gpusim::mps::SharePolicy;
use memgap::gpusim::GpuSpec;
use memgap::models::spec::ModelSpec;
use memgap::replication::run_replicated;
use memgap::util::cli::Args;
use memgap::workload::{generate, WorkloadConfig};

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let opts = if args.bool_or("quick", false) {
        FigOpts::quick()
    } else {
        FigOpts::default()
    };
    let gpu = GpuSpec::h100_64g();

    for spec in [ModelSpec::opt_1_3b(), ModelSpec::opt_2_7b()] {
        println!("==================== {} ====================", spec.name);
        let reqs = generate(&WorkloadConfig::sharegpt(opts.requests().max(800), 0));

        // Baseline: single instance, MAX batch, full memory (vLLM default).
        let bmax = roofline_figs::max_batch(&gpu, &spec);
        let max_cfg = OfflineConfig::new(spec.clone(), bmax);
        let max_run = run_replicated(&max_cfg, 1, SharePolicy::Mps, &reqs, 1.0)?;
        println!(
            "MAX (B={bmax}):            {:>8.0} tok/s  ITL {:>6.1} ms  CPU {:>4.1}%  DRAM {:>4.1}%",
            max_run.throughput_tps,
            max_run.mean_itl * 1e3,
            100.0 * max_run.cpu_time_frac,
            100.0 * max_run.mean_dram_util,
        );

        // BCA under the relaxed SLO -> replica memory share.
        let base1 = OfflineConfig::new(spec.clone(), 1);
        let profile = BcaProfile::measure(&base1, &bca_figs::profile_grid(&opts), opts.requests())?;
        let Some(rec) = bca::recommend(&profile, Constraints::relaxed(&profile)) else {
            println!("no feasible B_opt — model needs all memory (skipping replication)");
            continue;
        };
        let plan = bca::memory_plan(&gpu, &spec, rec.point.kv_usage);
        let frac = plan.engine_mem_fraction().max(0.05);
        let fit = ((1.0 / frac) as usize).clamp(1, 4);
        println!(
            "B_opt={} (relaxed SLO) -> each replica needs {:.0}% of usable memory; {} fit",
            rec.b_opt,
            100.0 * frac,
            fit
        );

        for policy in [SharePolicy::Fcfs, SharePolicy::Mps] {
            for n in 1..=fit {
                let cfg = OfflineConfig::new(spec.clone(), rec.b_opt);
                let rep = run_replicated(&cfg, n, policy, &reqs, frac)?;
                let vs_max = 100.0 * (rep.throughput_tps / max_run.throughput_tps - 1.0);
                println!(
                    "{:?} x{n}:  {:>8.0} tok/s ({:+.1}% vs MAX)  ITL {:>6.1} ms  CPU {:>4.1}%  DRAM {:>4.1}%",
                    policy,
                    rep.throughput_tps,
                    vs_max,
                    rep.mean_itl * 1e3,
                    100.0 * rep.cpu_time_frac,
                    100.0 * rep.mean_dram_util,
                );
            }
        }
        println!();
    }
    println!("(paper Table IV: replication beats MAX by +33.7% on OPT-1.3B, +12.8% on OPT-2.7B)");
    Ok(())
}
