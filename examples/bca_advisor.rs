//! BCA walkthrough (paper §VI): profile OPT-1.3B across batch sizes,
//! solve Eq. 2 under strict and relaxed SLOs, and print the memory plan
//! that frees GPU memory for concurrent workloads.
//!
//!     cargo run --release --example bca_advisor [-- --quick]

use memgap::bca::{self, BcaProfile, Constraints};
use memgap::coordinator::offline::OfflineConfig;
use memgap::figures::{bca_figs, FigOpts};
use memgap::gpusim::GpuSpec;
use memgap::models::spec::ModelSpec;
use memgap::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let opts = if args.bool_or("quick", false) {
        FigOpts::quick()
    } else {
        FigOpts::default()
    };
    let spec = ModelSpec::opt_1_3b();
    let base = OfflineConfig::new(spec.clone(), 1);
    let grid = bca_figs::profile_grid(&opts);

    println!("profiling {} across max-batch grid {:?} ...", spec.name, grid);
    let profile = BcaProfile::measure(&base, &grid, opts.requests())?;
    println!(
        "\n{:>9} {:>9} {:>12} {:>9} {:>8} {:>11}",
        "max_batch", "avg", "tok/s", "ITL ms", "KV %", "T/(B*T1)"
    );
    let t1 = profile.t1();
    for p in &profile.points {
        println!(
            "{:>9} {:>9.1} {:>12.0} {:>9.2} {:>8.1} {:>11.3}",
            p.max_batch,
            p.avg_batch,
            p.throughput_tps,
            p.itl * 1e3,
            100.0 * p.kv_usage,
            p.throughput_tps / (p.avg_batch.max(1.0) * t1)
        );
    }

    for (name, c) in [
        ("STRICT (2x ITL@32)", Constraints::strict(&profile)),
        ("RELAXED (4x ITL@32)", Constraints::relaxed(&profile)),
    ] {
        println!("\n--- {name}: SLO {:.2} ms, eps {} ---", c.slo_itl * 1e3, c.epsilon);
        match bca::recommend(&profile, c) {
            Some(r) => {
                println!("B_opt              : {}", r.b_opt);
                println!(
                    "throughput vs MAX  : {:.1} %",
                    100.0 * r.throughput_vs_max
                );
                println!(
                    "ITL vs MAX         : -{:.1} %",
                    100.0 * r.itl_reduction_vs_max
                );
                let plan = bca::memory_plan(&GpuSpec::h100_64g(), &spec, r.point.kv_usage);
                println!(
                    "memory plan        : weights {:.1} GB | KV used {:.1} GB | FREED {:.1} GB ({:.0} % of card) | other {:.1} GB",
                    plan.weights_gb,
                    plan.kv_used_gb,
                    plan.kv_freed_gb,
                    100.0 * plan.freed_frac(),
                    plan.other_gb
                );
                println!(
                    "replicas that fit  : {}",
                    (1.0 / plan.engine_mem_fraction().max(0.05)) as usize
                );
            }
            None => println!("no feasible batch size under these constraints"),
        }
    }
    Ok(())
}
